//! The process tier: address mapping as a *service*.  A
//! [`RemoteEngine`] scatter/gathers [`PtrBatch`]es and walk step-ranges
//! across N worker connections speaking a length-prefixed binary
//! protocol over Unix-domain sockets — either to worker **processes**
//! it spawns and supervises ([`RemoteEngine::spawn`]), or to a shared
//! multi-tenant [`daemon`](crate::daemon)
//! ([`RemoteEngine::connect`]).  Same [`AddressEngine`] contract,
//! served from outside the client's address space.
//!
//! ## Protocol (v2: epoch sessions)
//!
//! Every message is one *frame*: a little-endian `u32` byte length
//! followed by that many body bytes.  A body starts with a versioned
//! header (`MAGIC u32`, [`PROTOCOL_VERSION`] `u16`, op `u8`) so a
//! mismatched peer fails loudly instead of mis-decoding.
//!
//! Protocol v1 shipped a full [`EngineCtx`] snapshot — layout, base
//! table, executing thread, topology — in **every** request.  v2
//! amortizes it: a session *installs* the snapshot once under a client-
//! chosen **epoch** number, and steady-state requests carry only the
//! epoch plus the op payload.  A request naming an epoch the session
//! doesn't have is answered with a *stale-epoch* status and served
//! nothing; the client re-installs and retries, giving up loudly after
//! [`RemoteEngine::MAX_STALE_REINSTALLS`] rounds (`stale_failures` in
//! [`RemoteClientStats`]).
//!
//! | op | request payload | ok-response payload |
//! |----|-----------------|---------------------|
//! | `InstallCtx` | `epoch u64`, `priority u8`, ctx snapshot | — |
//! | `Translate` | `epoch u64`, `n u32`, n×ptr, n×`u64` inc | `n u32`, n×ptr, n×`u64` sysva, n×`u8` loc |
//! | `Increment` | `epoch u64`, `n u32`, n×ptr, n×`u64` inc | `n u32`, n×ptr |
//! | `Walk`      | `epoch u64`, start ptr, `inc u64`, `steps u64` | as `Translate` |
//! | `Ping`      | —               | — (calibration round-trip) |
//! | `Shutdown`  | —               | — (session ends after ack) |
//!
//! Responses echo the header with a status byte: `0` ok, `1` error +
//! `u32` len + UTF-8 message, `2` **stale epoch** (re-install and retry),
//! `3` **shed** (the daemon's admission control refused the request —
//! loud failure, never retried).  Requests are **framed per shard**: a
//! batch of `n` requests fans out to `k = clamp(n / min_shard_len, 1,
//! workers)` contiguous shards, one frame to worker `i` per shard `i`
//! (prefixed by an `InstallCtx` frame when that connection's installed
//! fingerprint is stale — install + op are pipelined in one write), and
//! the replies are spliced back **in shard order** — the same
//! order-preserving splice as [`ShardedEngine`](super::ShardedEngine),
//! so output is bit-identical to the inner engine at any worker count
//! (`rust/tests/remote_engine.rs` pins this over the NPB layouts at
//! 1/2/4 workers).  Walks shard over the step range with
//! [`increment_general`] origin offsets, guarded by
//! `inc.checked_mul(steps)` exactly like the thread tier.
//!
//! ## Worker lifecycle & failure semantics
//!
//! [`RemoteEngine::spawn`] launches `pgas-hw serve-engine --socket S`
//! once per worker (binary resolution: `PGAS_HW_WORKER_BIN`, the
//! current executable when it *is* `pgas-hw`, else a `pgas-hw` sibling
//! of the current executable) and connects with a bounded retry loop.
//! Each spawned worker serves exactly one client session
//! (`daemon::session::handle_frame` with the host-only backend) and
//! exits when the connection closes.  [`RemoteEngine::connect`] opens
//! N connections to one already-running daemon instead — each
//! connection is its own session with its own epochs.
//!
//! Failure is never silent: connect timeouts, short reads, stalled
//! peers (socket read timeout) and worker death all surface as
//! [`EngineError::Backend`] naming the worker, and the **in-flight
//! request fails loudly** (outputs are committed only after every shard
//! reply decodes and the total length equals the request length — a
//! short response can never be returned as a truncated success).
//! Recovery is **per-connection**: surviving connections are drained
//! back to a frame boundary, and only the failed ones are reconnected
//! (respawned in spawn mode) with exponential backoff + jitter under a
//! retry cap ([`RemoteEngine::reconnects`] counts these).  Only when a
//! heal fails outright is the whole pool torn down and rebuilt lazily
//! ([`RemoteEngine::restarts`]); `kill_worker` is the chaos hook the
//! tests use, `force_epoch_mismatch` the one for the stale-epoch path.
//! For *scheduled* faults, [`RemoteEngine::with_chaos`] installs a
//! seeded [`FaultPlan`] consulted once per session exchange: drops,
//! kills, forced stale epochs, and corrupt/truncated frames, all
//! reproducible from the seed.

use std::io::{ErrorKind, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::fault::{FaultPlan, WireFault};
use super::{
    AddressEngine, BatchOut, EngineCtx, EngineError, EngineSelector, PtrBatch,
};
use crate::daemon::session::{handle_frame, ExecBackend, SessionState};
use crate::sptr::{
    ctx_fingerprint, increment_general, ArrayLayout, BaseTable, Locality,
    SharedPtr, WireReader, WireWriter,
};

/// Version of the frame format.  Bumped on any wire-shape change; the
/// server refuses mismatched requests with a loud error naming both
/// versions.  v2: epoch sessions (`InstallCtx` + epoch-tagged ops,
/// stale-epoch and shed statuses).
pub const PROTOCOL_VERSION: u16 = 2;

/// "PGAS" — frame bodies open with this so a desynced or foreign peer
/// is detected immediately.
pub const MAGIC: u32 = 0x5047_4153;

/// Upper bound on one frame body; a corrupt length prefix must not OOM
/// the peer.
pub(crate) const MAX_FRAME: usize = 1 << 30;

/// Response status bytes.
pub(crate) const STATUS_OK: u8 = 0;
pub(crate) const STATUS_ERR: u8 = 1;
/// The request named an epoch the session doesn't have installed; the
/// client should `InstallCtx` and retry.
pub(crate) const STATUS_STALE_EPOCH: u8 = 2;
/// Admission control refused the request (quota / capacity).  Loud,
/// terminal for the request: clients must NOT retry.
pub(crate) const STATUS_SHED: u8 = 3;
/// The daemon is draining for shutdown: in-flight requests finish,
/// new frames are refused with this status.  Terminal for the
/// request; clients should fail over to another tier.
pub(crate) const STATUS_DRAINING: u8 = 4;

/// Wire bytes of one batch-shaped result (ptr 20 + sysva 8 + loc 1).
const RESULT_WIRE_BYTES: usize = 29;

/// Conservative size of a reply frame carrying `n` batch-shaped
/// results (header + count + columns).
pub(crate) fn reply_frame_bytes(n: usize) -> usize {
    64 + n.saturating_mul(RESULT_WIRE_BYTES)
}

/// Refuse a shard whose request frame — or whose *reply* — would blow
/// the frame cap, before anything is sent: a too-large frame would
/// otherwise kill the worker on receipt (or on reply) and loop through
/// heals without ever succeeding.
fn check_frame_budget(request_len: usize, results: usize) -> Result<(), EngineError> {
    if request_len > MAX_FRAME || reply_frame_bytes(results) > MAX_FRAME {
        return Err(EngineError::Backend(format!(
            "remote: a shard of {results} requests ({request_len}-byte frame) \
             would exceed the {MAX_FRAME}-byte frame cap; use more workers \
             or split the batch"
        )));
    }
    Ok(())
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Op {
    Translate = 0,
    Increment = 1,
    Walk = 2,
    Ping = 3,
    Shutdown = 4,
    InstallCtx = 5,
}

impl Op {
    pub(crate) fn from_u8(v: u8) -> Option<Op> {
        match v {
            0 => Some(Op::Translate),
            1 => Some(Op::Increment),
            2 => Some(Op::Walk),
            3 => Some(Op::Ping),
            4 => Some(Op::Shutdown),
            5 => Some(Op::InstallCtx),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------- frames

pub(crate) fn write_frame(
    stream: &mut UnixStream,
    body: &[u8],
) -> std::io::Result<()> {
    let len = u32::try_from(body.len()).map_err(|_| {
        std::io::Error::new(ErrorKind::InvalidInput, "frame exceeds u32 length")
    })?;
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// Read one frame.  `Ok(None)` is a clean end-of-stream *at a frame
/// boundary* (the peer closed between requests); EOF mid-frame is a
/// short read and errors.
pub(crate) fn read_frame(
    stream: &mut UnixStream,
) -> std::io::Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    match stream.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Ok(Some(body))
}

// ------------------------------------------------------------- encoding

fn begin_body(op: Op) -> WireWriter {
    let mut w = WireWriter::new();
    w.put_u32(MAGIC);
    w.put_u16(PROTOCOL_VERSION);
    w.put_u8(op as u8);
    w
}

/// `InstallCtx`: epoch, priority flag, then the full ctx snapshot —
/// the only v2 frame that carries layout/table/topology bytes.
pub(crate) fn encode_install_request(
    epoch: u64,
    priority: bool,
    ctx: &EngineCtx,
) -> Vec<u8> {
    let mut w = begin_body(Op::InstallCtx);
    w.put_u64(epoch);
    w.put_u8(priority as u8);
    w.put_layout(ctx.layout());
    w.put_u32(ctx.mythread());
    w.put_topology(ctx.topo());
    w.put_table(ctx.table());
    w.into_bytes()
}

/// A steady-state map request: epoch + pointers, **no ctx snapshot**.
pub(crate) fn encode_map_request(
    op: Op,
    epoch: u64,
    ptrs: &[SharedPtr],
    incs: &[u64],
) -> Vec<u8> {
    let mut w = begin_body(op);
    w.put_u64(epoch);
    w.put_u32(ptrs.len() as u32);
    for p in ptrs {
        w.put_ptr(p);
    }
    for &i in incs {
        w.put_u64(i);
    }
    w.into_bytes()
}

pub(crate) fn encode_walk_request(
    epoch: u64,
    start: SharedPtr,
    inc: u64,
    steps: u64,
) -> Vec<u8> {
    let mut w = begin_body(Op::Walk);
    w.put_u64(epoch);
    w.put_ptr(&start);
    w.put_u64(inc);
    w.put_u64(steps);
    w.into_bytes()
}

pub(crate) fn encode_simple_request(op: Op) -> Vec<u8> {
    begin_body(op).into_bytes()
}

pub(crate) fn ok_header() -> WireWriter {
    let mut w = WireWriter::new();
    w.put_u32(MAGIC);
    w.put_u16(PROTOCOL_VERSION);
    w.put_u8(STATUS_OK);
    w
}

/// A non-ok reply: header + status + `u32` len + UTF-8 message.
pub(crate) fn reply_status_body(status: u8, msg: &str) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.put_u32(MAGIC);
    w.put_u16(PROTOCOL_VERSION);
    w.put_u8(status);
    let bytes = msg.as_bytes();
    w.put_u32(bytes.len() as u32);
    w.put_bytes(bytes);
    w.into_bytes()
}

pub(crate) fn error_body(msg: &str) -> Vec<u8> {
    reply_status_body(STATUS_ERR, msg)
}

pub(crate) fn encode_batch_out(w: &mut WireWriter, out: &BatchOut) {
    w.put_u32(out.len() as u32);
    for p in &out.ptrs {
        w.put_ptr(p);
    }
    for &s in &out.sysva {
        w.put_u64(s);
    }
    for &l in &out.loc {
        w.put_locality(l);
    }
}

// ------------------------------------------------------------- decoding

/// Peek a reply body's status byte without consuming it (`None` for a
/// body too short or desynced to carry one — full decoding surfaces
/// the real error).
fn body_status(body: &[u8]) -> Option<u8> {
    let mut r = WireReader::new(body);
    (r.get_u32() == Ok(MAGIC) && r.get_u16() == Ok(PROTOCOL_VERSION))
        .then(|| r.get_u8().ok())
        .flatten()
}

/// Check a response header; on a non-ok status, surface the server's
/// message (labelled by kind: shed and stale-epoch replies carry their
/// own vocabulary so callers and logs can tell them apart).  Returns a
/// reader positioned at the payload.
fn open_response(body: &[u8]) -> Result<WireReader<'_>, EngineError> {
    let mut r = WireReader::new(body);
    let backend = EngineError::Backend;
    let magic = r.get_u32().map_err(|e| backend(format!("remote: {e}")))?;
    if magic != MAGIC {
        return Err(backend(format!(
            "remote: response magic {magic:#x} != {MAGIC:#x} (desynced stream?)"
        )));
    }
    let version = r.get_u16().map_err(|e| backend(format!("remote: {e}")))?;
    if version != PROTOCOL_VERSION {
        return Err(backend(format!(
            "remote: server speaks protocol v{version}, client v{PROTOCOL_VERSION}"
        )));
    }
    let status = r.get_u8().map_err(|e| backend(format!("remote: {e}")))?;
    if status != STATUS_OK {
        let n = r.get_count(1).map_err(|e| backend(format!("remote: {e}")))?;
        let msg = r.get_bytes(n).map_err(|e| backend(format!("remote: {e}")))?;
        let msg = String::from_utf8_lossy(msg);
        let kind = match status {
            STATUS_STALE_EPOCH => "stale epoch",
            STATUS_SHED => "request shed",
            STATUS_DRAINING => "server draining",
            _ => "server error",
        };
        return Err(backend(format!("remote: {kind}: {msg}")));
    }
    Ok(r)
}

fn decode_batch_response(body: &[u8], into: &mut BatchOut) -> Result<(), EngineError> {
    let mut r = open_response(body)?;
    let wire = |e: crate::sptr::WireError| {
        EngineError::Backend(format!("remote: malformed response: {e}"))
    };
    // count validated against the frame before any reserve sized by it
    let n = r.get_count(RESULT_WIRE_BYTES).map_err(wire)?;
    into.reserve(n);
    let base = into.ptrs.len();
    for _ in 0..n {
        let p = r.get_ptr().map_err(wire)?;
        into.ptrs.push(p);
    }
    for _ in 0..n {
        into.sysva.push(r.get_u64().map_err(wire)?);
    }
    for _ in 0..n {
        into.loc.push(r.get_locality().map_err(wire)?);
    }
    debug_assert_eq!(into.ptrs.len(), base + n);
    r.finish().map_err(wire)
}

fn decode_ptrs_response(
    body: &[u8],
    into: &mut Vec<SharedPtr>,
) -> Result<(), EngineError> {
    let mut r = open_response(body)?;
    let wire = |e: crate::sptr::WireError| {
        EngineError::Backend(format!("remote: malformed response: {e}"))
    };
    let n = r.get_count(20).map_err(wire)?; // 20 = wire bytes per ptr
    into.reserve(n);
    for _ in 0..n {
        into.push(r.get_ptr().map_err(wire)?);
    }
    r.finish().map_err(wire)
}

// ------------------------------------------------------- worker (server)

/// One client session on an established stream: loop
/// read-frame/serve/write-frame until the client disconnects or sends
/// `Shutdown`.  The frame handler is the daemon's
/// ([`daemon::session::handle_frame`](crate::daemon::session::handle_frame))
/// with the host-only backend — a spawned worker IS a single-tenant
/// daemon session, epochs and all.  Split out so the protocol is
/// unit-testable over a socketpair without spawning processes.
fn serve_session(stream: &mut UnixStream) -> Result<(), String> {
    let mut sess = SessionState::new(0);
    let exec = ExecBackend::host_only();
    loop {
        let frame = match read_frame(stream) {
            Ok(Some(f)) => f,
            // Clean disconnect at a frame boundary: the supervising
            // client is gone, this worker's job is done.
            Ok(None) => return Ok(()),
            Err(e) => return Err(format!("serve-engine: read: {e}")),
        };
        let (reply, shutdown) = handle_frame(&frame, &mut sess, &exec);
        write_frame(stream, &reply)
            .map_err(|e| format!("serve-engine: write: {e}"))?;
        if shutdown {
            return Ok(());
        }
    }
}

/// The worker side of the remote tier — what `pgas-hw serve-engine
/// --socket PATH` runs: bind `socket`, accept exactly **one** client
/// session, serve it to completion, clean up, exit.  The supervising
/// [`RemoteEngine`] owns the process lifetime; a fresh worker gets a
/// fresh socket, so a lingering process can never serve a stale path.
/// (For many sessions over one socket, that's `pgas-hw daemon`.)
pub fn serve(socket: &Path) -> Result<(), String> {
    let _ = std::fs::remove_file(socket);
    let listener = UnixListener::bind(socket)
        .map_err(|e| format!("serve-engine: bind {}: {e}", socket.display()))?;
    let (mut stream, _) = listener
        .accept()
        .map_err(|e| format!("serve-engine: accept: {e}"))?;
    let result = serve_session(&mut stream);
    let _ = std::fs::remove_file(socket);
    result
}

// ------------------------------------------------------- client (engine)

struct Worker {
    /// The supervised process in spawn mode; `None` when this is a
    /// connection to a shared daemon.
    child: Option<Child>,
    stream: UnixStream,
    socket: PathBuf,
    /// What this connection's session has installed: `(ctx fingerprint,
    /// epoch)`.  `None` right after (re)connect.
    installed: Option<(u64, u64)>,
}

impl Worker {
    fn reap(&mut self) {
        if let Some(child) = &mut self.child {
            let _ = child.kill();
            let _ = child.wait();
            // per-worker socket file in spawn mode only; a daemon's
            // socket belongs to the daemon
            let _ = std::fs::remove_file(&self.socket);
        }
    }
}

/// How the pool gets its connections.
enum WorkerMode {
    /// Spawn + supervise one `serve-engine` process per worker.
    Spawn { bin: PathBuf, dir: PathBuf },
    /// Connect N sessions to one already-running `pgas-hw daemon`.
    Connect { socket: PathBuf },
}

/// Resolve the worker executable: explicit env override, the current
/// executable when it *is* the CLI, else a `pgas-hw` next to (or one
/// directory above — test binaries live in `target/*/deps/`) the
/// current executable.
fn resolve_worker_bin() -> Result<PathBuf, EngineError> {
    if let Some(p) = std::env::var_os("PGAS_HW_WORKER_BIN") {
        return Ok(PathBuf::from(p));
    }
    let exe = std::env::current_exe().map_err(|e| {
        EngineError::Backend(format!("remote: cannot resolve current exe: {e}"))
    })?;
    if exe.file_stem().is_some_and(|s| s == "pgas-hw") {
        return Ok(exe);
    }
    let mut dirs: Vec<&Path> = Vec::new();
    if let Some(d) = exe.parent() {
        dirs.push(d);
        if let Some(p) = d.parent() {
            dirs.push(p);
        }
    }
    for d in dirs {
        let cand = d.join("pgas-hw");
        if cand.is_file() {
            return Ok(cand);
        }
    }
    Err(EngineError::Backend(
        "remote: cannot locate the `pgas-hw` worker binary; set \
         PGAS_HW_WORKER_BIN or use RemoteEngine::spawn_with_bin"
            .into(),
    ))
}

/// Client-side session/recovery counters, snapshotted into
/// `MachineResult::stats_txt` when a remote tier is installed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RemoteClientStats {
    /// Whole-pool rebuilds (only after a per-connection heal failed).
    pub restarts: u64,
    /// Individual connections healed (reconnect/respawn with backoff).
    pub reconnects: u64,
    /// `InstallCtx` messages sent (ctx changed, or fresh connection).
    pub installs: u64,
    /// Installs forced by a stale-epoch reply (server lost the session
    /// state, or the chaos hook desynced it).
    pub reinstalls: u64,
    /// Steady-state requests that rode an already-installed epoch.
    pub epoch_hits: u64,
    /// Requests that failed loudly because a connection stayed stale
    /// after [`RemoteEngine::MAX_STALE_REINSTALLS`] re-installs.
    pub stale_failures: u64,
}

/// Process-pool / daemon-client backend: the same scatter/gather +
/// order-preserving splice as [`ShardedEngine`](super::ShardedEngine),
/// over worker connections instead of threads.  See the module docs for
/// the protocol and failure semantics.
pub struct RemoteEngine {
    /// One mutex over the whole pool: a request owns every stream it
    /// scatters to until the gather completes, so streams can never
    /// interleave frames from two requests.
    pool: Mutex<Vec<Worker>>,
    /// Configured pool size; the live pool can be smaller (empty)
    /// after a failed heal, and is re-grown to this target by
    /// `ensure_pool` on the next request.
    target_workers: usize,
    mode: WorkerMode,
    min_shard_len: usize,
    timeout: Duration,
    /// Monotonic worker generation — keeps respawned socket names
    /// unique.
    generation: AtomicU64,
    /// Client-assigned epoch numbers, never reused.
    next_epoch: AtomicU64,
    /// Emulate the protocol-v1 behavior: ship the ctx snapshot with
    /// every request (the bench baseline the epoch path is judged
    /// against).
    reinstall_every_request: bool,
    /// Installed into every session: routes this client through the
    /// daemon's priority scheduling ring and accelerator-lease path.
    priority: bool,
    /// Seeded wire-fault schedule (drops, kills, forced stale epochs,
    /// corrupt/truncated frames) consulted once per session exchange.
    chaos: Option<Arc<FaultPlan>>,
    restarts: AtomicU64,
    reconnects: AtomicU64,
    installs: AtomicU64,
    reinstalls: AtomicU64,
    epoch_hits: AtomicU64,
    stale_failures: AtomicU64,
}

impl RemoteEngine {
    /// Below this many requests per shard the serialization + socket
    /// hop cannot pay for itself; smaller batches go to worker 0 whole.
    pub const DEFAULT_MIN_SHARD_LEN: usize = 4096;

    /// Per-I/O timeout: a peer that neither answers nor dies within
    /// this window is treated as dead (stalls must not hang the
    /// client).
    pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(10);

    /// Reconnect attempts per failed connection before the pool gives
    /// up and falls back to a full restart.
    const RECONNECT_ATTEMPTS: u32 = 4;

    /// Re-install + retry rounds per request before repeated
    /// stale-epoch replies on one connection fail loudly — a session
    /// that cannot hold installed state is desynced, not transient,
    /// and retrying forever would hide it.
    pub const MAX_STALE_REINSTALLS: u32 = 3;

    /// Spawn `workers` worker processes (clamped to ≥ 1) running the
    /// auto-resolved `pgas-hw` binary's `serve-engine` subcommand.
    pub fn spawn(workers: usize) -> Result<Self, EngineError> {
        Self::spawn_with_bin(resolve_worker_bin()?, workers)
    }

    /// [`spawn`](Self::spawn) with an explicit worker executable (the
    /// integration tests pass `env!("CARGO_BIN_EXE_pgas-hw")`).
    pub fn spawn_with_bin(
        bin: impl Into<PathBuf>,
        workers: usize,
    ) -> Result<Self, EngineError> {
        let dir = std::env::temp_dir().join(format!(
            "pgas-hw-remote-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0)
        ));
        std::fs::create_dir_all(&dir).map_err(|e| {
            EngineError::Backend(format!(
                "remote: cannot create socket dir {}: {e}",
                dir.display()
            ))
        })?;
        Self::with_mode(WorkerMode::Spawn { bin: bin.into(), dir }, workers)
    }

    /// Open `connections` client sessions to an already-running
    /// `pgas-hw daemon` on `socket`.  Each connection is an
    /// independent session (own epochs, own tenant id daemon-side);
    /// batches fan out over them exactly like spawned workers.
    pub fn connect(
        socket: impl Into<PathBuf>,
        connections: usize,
    ) -> Result<Self, EngineError> {
        Self::with_mode(
            WorkerMode::Connect { socket: socket.into() },
            connections,
        )
    }

    fn with_mode(mode: WorkerMode, workers: usize) -> Result<Self, EngineError> {
        let engine = Self {
            pool: Mutex::new(Vec::new()),
            target_workers: workers.max(1),
            mode,
            min_shard_len: Self::DEFAULT_MIN_SHARD_LEN,
            timeout: Self::DEFAULT_TIMEOUT,
            generation: AtomicU64::new(0),
            next_epoch: AtomicU64::new(0),
            reinstall_every_request: false,
            priority: false,
            chaos: None,
            restarts: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            installs: AtomicU64::new(0),
            reinstalls: AtomicU64::new(0),
            epoch_hits: AtomicU64::new(0),
            stale_failures: AtomicU64::new(0),
        };
        {
            let mut pool = engine.pool.lock().expect("fresh mutex");
            engine.ensure_pool(&mut pool)?;
        }
        Ok(engine)
    }

    /// Override the inline-serve threshold (the conformance tests set 1
    /// to force real multi-worker fan-out on small batches).
    pub fn with_min_shard_len(mut self, n: usize) -> Self {
        self.min_shard_len = n.max(1);
        self
    }

    /// Override the per-I/O timeout.
    pub fn with_timeout(mut self, t: Duration) -> Self {
        self.timeout = t;
        self
    }

    /// Ship the ctx snapshot with **every** request (fresh epoch each
    /// time) — the protocol-v1 cost model, kept as the measured
    /// baseline the epoch-session path must beat.
    pub fn with_reinstall_every_request(mut self, on: bool) -> Self {
        self.reinstall_every_request = on;
        self
    }

    /// Mark this client's sessions high-priority: the daemon schedules
    /// them on the priority ring and lets them jump the accelerator
    /// lease queue.
    pub fn with_priority(mut self, on: bool) -> Self {
        self.priority = on;
        self
    }

    /// Install a seeded wire-fault schedule: one draw per session
    /// exchange can sever a connection, kill a worker, desync the
    /// installed epochs, or corrupt/truncate the outgoing op frame.
    /// Every injected fault surfaces as a loud [`EngineError::Backend`]
    /// and exercises the same heal/re-install paths a real failure
    /// would — reproducibly, from the plan's seed.
    pub fn with_chaos(mut self, plan: Arc<FaultPlan>) -> Self {
        self.chaos = Some(plan);
        self
    }

    /// Worker-pool size.
    pub fn workers(&self) -> usize {
        self.pool.lock().map(|p| p.len()).unwrap_or(0)
    }

    /// Whole-pool rebuilds (the last-resort recovery).
    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    /// Individual connections healed after a mid-request failure.
    pub fn reconnects(&self) -> u64 {
        self.reconnects.load(Ordering::Relaxed)
    }

    /// `InstallCtx` messages sent.
    pub fn installs(&self) -> u64 {
        self.installs.load(Ordering::Relaxed)
    }

    /// Installs forced by stale-epoch replies.
    pub fn reinstalls(&self) -> u64 {
        self.reinstalls.load(Ordering::Relaxed)
    }

    /// Steady-state requests served against an installed epoch.
    pub fn epoch_hits(&self) -> u64 {
        self.epoch_hits.load(Ordering::Relaxed)
    }

    /// Requests failed loudly after exhausting the stale re-install
    /// budget on one connection.
    pub fn stale_failures(&self) -> u64 {
        self.stale_failures.load(Ordering::Relaxed)
    }

    /// All client counters in one snapshot.
    pub fn client_stats(&self) -> RemoteClientStats {
        RemoteClientStats {
            restarts: self.restarts(),
            reconnects: self.reconnects(),
            installs: self.installs(),
            reinstalls: self.reinstalls(),
            epoch_hits: self.epoch_hits(),
            stale_failures: self.stale_failures(),
        }
    }

    /// Chaos hook (tests/ops): force-kill worker `slot`'s process (or
    /// sever its daemon connection) without telling the client side.
    /// The next request touching the dead stream must fail loudly and
    /// heal the connection.
    pub fn kill_worker(&self, slot: usize) -> Result<(), EngineError> {
        let mut pool = self.lock_pool()?;
        let w = pool.get_mut(slot).ok_or_else(|| {
            EngineError::Backend(format!("remote: no worker slot {slot}"))
        })?;
        match &mut w.child {
            Some(child) => {
                let _ = child.kill();
                let _ = child.wait();
            }
            None => {
                let _ = w.stream.shutdown(std::net::Shutdown::Both);
            }
        }
        Ok(())
    }

    /// Chaos hook: desync every connection's client-side epoch so the
    /// next request draws a stale-epoch reply and exercises the
    /// re-install + retry path.
    pub fn force_epoch_mismatch(&self) {
        if let Ok(mut pool) = self.pool.lock() {
            for w in pool.iter_mut() {
                if let Some((fp, epoch)) = w.installed {
                    w.installed = Some((fp, epoch ^ 0x5A5A_5A5A));
                }
            }
        }
    }

    fn lock_pool(&self) -> Result<std::sync::MutexGuard<'_, Vec<Worker>>, EngineError> {
        self.pool.lock().map_err(|_| {
            EngineError::Backend("remote: pool mutex poisoned".into())
        })
    }

    fn connect_worker(&self, slot: usize) -> Result<Worker, EngineError> {
        match &self.mode {
            WorkerMode::Spawn { bin, dir } => self.spawn_worker(bin, dir, slot),
            WorkerMode::Connect { socket } => {
                let deadline = Instant::now() + self.timeout;
                let stream = loop {
                    match UnixStream::connect(socket) {
                        Ok(s) => break s,
                        Err(e) => {
                            if Instant::now() >= deadline {
                                return Err(EngineError::Backend(format!(
                                    "remote: cannot connect session {slot} to \
                                     daemon {} within {:?}: {e}",
                                    socket.display(),
                                    self.timeout
                                )));
                            }
                            std::thread::sleep(Duration::from_millis(1));
                        }
                    }
                };
                self.set_io_timeouts(&stream, slot)?;
                Ok(Worker {
                    child: None,
                    stream,
                    socket: socket.clone(),
                    installed: None,
                })
            }
        }
    }

    fn set_io_timeouts(
        &self,
        stream: &UnixStream,
        slot: usize,
    ) -> Result<(), EngineError> {
        for (what, res) in [
            ("read", stream.set_read_timeout(Some(self.timeout))),
            ("write", stream.set_write_timeout(Some(self.timeout))),
        ] {
            res.map_err(|e| {
                EngineError::Backend(format!(
                    "remote: worker {slot}: set {what} timeout: {e}"
                ))
            })?;
        }
        Ok(())
    }

    fn spawn_worker(
        &self,
        bin: &Path,
        dir: &Path,
        slot: usize,
    ) -> Result<Worker, EngineError> {
        let generation = self.generation.fetch_add(1, Ordering::Relaxed);
        let socket = dir.join(format!("w{slot}-g{generation}.sock"));
        // stderr stays inherited: a crashing worker must be loud.
        let mut child = Command::new(bin)
            .arg("serve-engine")
            .arg("--socket")
            .arg(&socket)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .spawn()
            .map_err(|e| {
                EngineError::Backend(format!(
                    "remote: cannot spawn worker {slot} ({}): {e}",
                    bin.display()
                ))
            })?;
        // Connect with a bounded retry loop: the worker needs a moment
        // to bind its socket; a worker that exits during startup is
        // reported with its status instead of a bare timeout.
        let deadline = Instant::now() + self.timeout;
        let stream = loop {
            match UnixStream::connect(&socket) {
                Ok(s) => break s,
                Err(connect_err) => {
                    if let Ok(Some(status)) = child.try_wait() {
                        return Err(EngineError::Backend(format!(
                            "remote: worker {slot} exited during startup \
                             ({status})"
                        )));
                    }
                    if Instant::now() >= deadline {
                        let _ = child.kill();
                        let _ = child.wait();
                        return Err(EngineError::Backend(format!(
                            "remote: worker {slot} did not accept on {} \
                             within {:?}: {connect_err}",
                            socket.display(),
                            self.timeout
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        };
        self.set_io_timeouts(&stream, slot)?;
        Ok(Worker { child: Some(child), stream, socket, installed: None })
    }

    /// How many shards a request of `n` items fans out to.
    fn fanout(&self, n: usize, workers: usize) -> usize {
        (n / self.min_shard_len).clamp(1, workers.max(1))
    }

    /// Grow the pool back to its configured size (no-op when full).
    /// On a connect failure everything opened so far is reaped and the
    /// pool left **empty** — never short — so a later request heals or
    /// errors loudly here instead of indexing past the pool.
    fn ensure_pool(&self, pool: &mut Vec<Worker>) -> Result<(), EngineError> {
        while pool.len() < self.target_workers {
            match self.connect_worker(pool.len()) {
                Ok(w) => pool.push(w),
                Err(e) => {
                    for w in pool.iter_mut() {
                        w.reap();
                    }
                    pool.clear();
                    return Err(EngineError::Backend(format!(
                        "remote: cannot (re)build the worker pool: {e}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Replace one dead connection in place: reconnect (spawn mode:
    /// respawn) with exponential backoff + jitter under a retry cap.
    /// The healed connection starts with no installed ctx.
    fn heal_worker(
        &self,
        pool: &mut [Worker],
        slot: usize,
    ) -> Result<(), EngineError> {
        pool[slot].reap();
        let mut last = String::new();
        for attempt in 0..Self::RECONNECT_ATTEMPTS {
            if attempt > 0 {
                // 2/4/8 ms, plus up to ~50% jitter so a herd of clients
                // healing off one daemon restart doesn't stampede it
                let base_ms = 1u64 << attempt;
                let jitter_us = std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .map(|d| d.subsec_nanos() as u64 / 1000)
                    .unwrap_or(0)
                    % (base_ms * 500);
                std::thread::sleep(
                    Duration::from_millis(base_ms)
                        + Duration::from_micros(jitter_us),
                );
            }
            match self.connect_worker(slot) {
                Ok(w) => {
                    pool[slot] = w;
                    self.reconnects.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
                Err(e) => last = e.to_string(),
            }
        }
        Err(EngineError::Backend(format!(
            "remote: worker {slot} not healed after \
             {} attempts: {last}",
            Self::RECONNECT_ATTEMPTS
        )))
    }

    /// Send each plan's frames to its worker slot and collect the
    /// replies per slot, in order.  On any failure the in-flight
    /// request is abandoned: surviving connections are **drained** back
    /// to a frame boundary (their pending replies read and discarded),
    /// dead ones are healed individually, and a loud error names the
    /// failed worker.  Only if a heal fails is the whole pool torn down
    /// ([`restarts`](Self::restarts)) for a lazy rebuild.
    fn scatter_gather(
        &self,
        pool: &mut Vec<Worker>,
        plan: &[(usize, Vec<Vec<u8>>)],
    ) -> Result<Vec<Vec<Vec<u8>>>, EngineError> {
        debug_assert!(plan.iter().all(|(slot, _)| *slot < pool.len()));
        let mut written = vec![0usize; plan.len()];
        let mut failure: Option<(usize, String)> = None;
        'scatter: for (i, (slot, frames)) in plan.iter().enumerate() {
            for frame in frames {
                if let Err(e) = write_frame(&mut pool[*slot].stream, frame) {
                    failure = Some((*slot, format!("send: {e}")));
                    break 'scatter;
                }
                written[i] += 1;
            }
        }
        let mut replies: Vec<Vec<Vec<u8>>> =
            plan.iter().map(|_| Vec::new()).collect();
        if failure.is_none() {
            'gather: for (i, (slot, frames)) in plan.iter().enumerate() {
                for _ in 0..frames.len() {
                    match read_frame(&mut pool[*slot].stream) {
                        Ok(Some(r)) => replies[i].push(r),
                        Ok(None) => {
                            failure =
                                Some((*slot, "worker closed mid-request".into()));
                            break 'gather;
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock
                            || e.kind() == ErrorKind::TimedOut =>
                        {
                            failure = Some((
                                *slot,
                                format!("timed out after {:?}", self.timeout),
                            ));
                            break 'gather;
                        }
                        Err(e) => {
                            failure = Some((*slot, format!("recv: {e}")));
                            break 'gather;
                        }
                    }
                }
            }
        }
        let Some((failed_slot, what)) = failure else {
            return Ok(replies);
        };
        // Drain survivors to a frame boundary: every frame written but
        // not yet answered gets its reply read and discarded, so the
        // stream (and the server session behind it) stays usable.  A
        // drain failure marks that connection dead too.
        let mut dead = vec![failed_slot];
        for (i, (slot, _)) in plan.iter().enumerate() {
            if *slot == failed_slot {
                continue;
            }
            let pending = written[i].saturating_sub(replies[i].len());
            for _ in 0..pending {
                match read_frame(&mut pool[*slot].stream) {
                    Ok(Some(_)) => {}
                    _ => {
                        dead.push(*slot);
                        break;
                    }
                }
            }
        }
        // Heal the dead connections in place; fall back to a full pool
        // restart only when a heal fails outright.
        let mut healed = true;
        for &slot in &dead {
            if self.heal_worker(pool, slot).is_err() {
                healed = false;
                break;
            }
        }
        let recovery = if healed {
            format!("{} connection(s) reconnected", dead.len())
        } else {
            for w in pool.iter_mut() {
                w.reap();
            }
            pool.clear();
            self.restarts.fetch_add(1, Ordering::Relaxed);
            // the *next* request's ensure_pool rebuilds (or errors
            // loudly); the pool is never left short
            "heal failed; pool torn down for rebuild".into()
        };
        Err(EngineError::Backend(format!(
            "remote: worker {failed_slot} failed mid-request ({what}); \
             request NOT served, {recovery}"
        )))
    }

    /// Ensure `pool[slot]`'s session has `ctx` installed, appending an
    /// `InstallCtx` frame when needed, and return the epoch to tag the
    /// op frame with.
    fn prep_worker(
        &self,
        worker: &mut Worker,
        fingerprint: u64,
        ctx: &EngineCtx,
        frames: &mut Vec<Vec<u8>>,
    ) -> u64 {
        if !self.reinstall_every_request {
            if let Some((fp, epoch)) = worker.installed {
                if fp == fingerprint {
                    self.epoch_hits.fetch_add(1, Ordering::Relaxed);
                    return epoch;
                }
            }
        }
        let epoch = self.next_epoch.fetch_add(1, Ordering::Relaxed) + 1;
        frames.push(encode_install_request(epoch, self.priority, ctx));
        worker.installed = Some((fingerprint, epoch));
        self.installs.fetch_add(1, Ordering::Relaxed);
        epoch
    }

    /// Apply one injected connection-level wire fault.  Frame-level
    /// faults (corrupt/truncate) are applied to the encoded plan by
    /// `session_exchange`; shed storms are a server-side injection.
    fn inject_wire_fault(&self, pool: &mut [Worker], fault: WireFault) {
        match fault {
            WireFault::Drop => {
                if let Some(w) = pool.first_mut() {
                    let _ = w.stream.shutdown(std::net::Shutdown::Both);
                }
            }
            WireFault::Kill => {
                if let Some(w) = pool.first_mut() {
                    match &mut w.child {
                        Some(child) => {
                            let _ = child.kill();
                            let _ = child.wait();
                        }
                        None => {
                            let _ =
                                w.stream.shutdown(std::net::Shutdown::Both);
                        }
                    }
                }
            }
            WireFault::Stale => {
                for w in pool.iter_mut() {
                    if let Some((fp, epoch)) = w.installed {
                        w.installed = Some((fp, epoch ^ 0x5A5A_5A5A));
                    }
                }
            }
            WireFault::Shed | WireFault::Corrupt | WireFault::Truncate => {}
        }
    }

    /// The epoch-session exchange shared by every sharded op: install
    /// where needed (pipelined with the op frame), scatter/gather,
    /// validate install acks, and serve stale-epoch replies with a
    /// bounded re-install + retry loop
    /// ([`MAX_STALE_REINSTALLS`](Self::MAX_STALE_REINSTALLS) rounds,
    /// then a loud failure counted in `stale_failures`).  `shards[i]`
    /// is `(result count, op-frame encoder)` for pool slot `i`;
    /// returns the op reply bodies in shard order.
    fn session_exchange(
        &self,
        pool: &mut Vec<Worker>,
        ctx: &EngineCtx,
        shards: &[(usize, &dyn Fn(u64) -> Vec<u8>)],
    ) -> Result<Vec<Vec<u8>>, EngineError> {
        let injected = self.chaos.as_deref().and_then(|p| p.wire_fault());
        if let Some(fault) = injected {
            self.inject_wire_fault(pool, fault);
        }
        let fingerprint =
            ctx_fingerprint(ctx.layout(), ctx.mythread(), ctx.topo(), ctx.table());
        let mut plan = Vec::with_capacity(shards.len());
        for (slot, (results, encode)) in shards.iter().enumerate() {
            let mut frames = Vec::with_capacity(2);
            let epoch = self.prep_worker(&mut pool[slot], fingerprint, ctx, &mut frames);
            let op_frame = encode(epoch);
            check_frame_budget(op_frame.len(), *results)?;
            frames.push(op_frame);
            plan.push((slot, frames));
        }
        match injected {
            // flip the first header byte of shard 0's op frame: the
            // server rejects the magic with an error reply and the
            // session survives
            Some(WireFault::Corrupt) => {
                if let Some(f) =
                    plan.first_mut().and_then(|(_, fs)| fs.last_mut())
                {
                    if let Some(b) = f.first_mut() {
                        *b ^= 0xFF;
                    }
                }
            }
            // cut the op body right after the header: framing stays
            // valid, the payload decode fails server-side
            Some(WireFault::Truncate) => {
                if let Some(f) =
                    plan.first_mut().and_then(|(_, fs)| fs.last_mut())
                {
                    f.truncate(8.min(f.len()));
                }
            }
            _ => {}
        }
        let replies = self.scatter_gather(pool, &plan)?;
        let mut out = Vec::with_capacity(shards.len());
        for (slot, mut bodies) in replies.into_iter().enumerate() {
            let mut op_body = bodies.pop().expect("one reply per frame");
            // install acks precede the op reply; a rejected install
            // (bad table, version skew) fails the request loudly
            for ack in &bodies {
                if let Err(e) = open_response(ack) {
                    pool[slot].installed = None;
                    return Err(EngineError::Backend(format!(
                        "remote: worker {slot} rejected InstallCtx: {e}"
                    )));
                }
            }
            // the session lost (or never had) our epoch: install a
            // fresh one and retry, under a budget — a connection that
            // stays stale across re-installs is desynced, not slow
            let mut attempts = 0;
            while body_status(&op_body) == Some(STATUS_STALE_EPOCH) {
                attempts += 1;
                if attempts > Self::MAX_STALE_REINSTALLS {
                    pool[slot].installed = None;
                    self.stale_failures.fetch_add(1, Ordering::Relaxed);
                    return Err(EngineError::Backend(format!(
                        "remote: worker {slot} still reports a stale epoch \
                         after {} re-installs — protocol desync",
                        Self::MAX_STALE_REINSTALLS
                    )));
                }
                self.reinstalls.fetch_add(1, Ordering::Relaxed);
                let epoch = self.next_epoch.fetch_add(1, Ordering::Relaxed) + 1;
                let frames = vec![
                    encode_install_request(epoch, self.priority, ctx),
                    shards[slot].1(epoch),
                ];
                self.installs.fetch_add(1, Ordering::Relaxed);
                pool[slot].installed = Some((fingerprint, epoch));
                let mut retry =
                    self.scatter_gather(pool, &[(slot, frames)])?;
                let mut rbodies = retry.pop().expect("one plan entry");
                let retried = rbodies.pop().expect("op reply");
                if let Err(e) = open_response(&rbodies[0]) {
                    pool[slot].installed = None;
                    return Err(EngineError::Backend(format!(
                        "remote: worker {slot} rejected InstallCtx on \
                         stale-epoch retry: {e}"
                    )));
                }
                op_body = retried;
            }
            out.push(op_body);
        }
        Ok(out)
    }

    /// Measure this pool's cost-model legs with real round-trips:
    /// `dispatch_ns` is the best of 8 pings (pure frame + socket + op
    /// overhead), `ns_per_ptr` the marginal per-pointer cost of a
    /// pool-wide increment batch.  Returns `(ns_per_ptr, dispatch_ns)`
    /// — the same shape as `Leon3Engine::calibrate`.  With epoch
    /// sessions the first increment installs the ctx and the best-of-3
    /// then measures the steady state.
    pub fn calibrate(&self) -> Result<(f64, f64), EngineError> {
        let mut dispatch_ns = f64::MAX;
        for _ in 0..8 {
            let t0 = Instant::now();
            self.ping()?;
            dispatch_ns = dispatch_ns.min(t0.elapsed().as_nanos() as f64);
        }
        // A batch wide enough to fan out over every worker.
        let n = self.min_shard_len.max(1024) * self.workers();
        let layout = ArrayLayout::new(64, 8, 16);
        let table = BaseTable::regular(16, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).expect("table covers layout");
        let mut batch = PtrBatch::with_capacity(n);
        for i in 0..n as u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 3), i % 4096);
        }
        let mut out = Vec::new();
        let mut best_ns = f64::MAX;
        for _ in 0..3 {
            let t0 = Instant::now();
            self.increment(&ctx, &batch, &mut out)?;
            best_ns = best_ns.min(t0.elapsed().as_nanos() as f64);
        }
        let ns_per_ptr = ((best_ns - dispatch_ns).max(0.0) / n as f64).max(0.05);
        Ok((ns_per_ptr, dispatch_ns))
    }

    /// One empty round-trip to worker 0 (liveness + dispatch cost).
    pub fn ping(&self) -> Result<(), EngineError> {
        let mut pool = self.lock_pool()?;
        self.ensure_pool(&mut pool)?;
        let plan = [(0usize, vec![encode_simple_request(Op::Ping)])];
        let replies = self.scatter_gather(&mut pool, &plan)?;
        open_response(&replies[0][0]).map(|_| ())
    }

    /// Shared map-request path for translate/increment.
    fn map_request(
        &self,
        op: Op,
        ctx: &EngineCtx,
        batch: &PtrBatch,
    ) -> Result<Vec<Vec<u8>>, EngineError> {
        let mut pool = self.lock_pool()?;
        self.ensure_pool(&mut pool)?;
        let k = self.fanout(batch.len(), pool.len());
        let chunk = batch.len().div_ceil(k);
        let mut encoders: Vec<(usize, Box<dyn Fn(u64) -> Vec<u8> + '_>)> =
            Vec::with_capacity(k);
        for i in 0..k {
            // Clamp both bounds: ceil-sized chunks can exhaust the
            // batch before the last shard, leaving a legal empty range.
            let lo = (i * chunk).min(batch.len());
            let hi = ((i + 1) * chunk).min(batch.len());
            let (ptrs, incs) = (&batch.ptrs[lo..hi], &batch.incs[lo..hi]);
            encoders.push((
                hi - lo,
                Box::new(move |epoch| encode_map_request(op, epoch, ptrs, incs)),
            ));
        }
        let shards: Vec<(usize, &dyn Fn(u64) -> Vec<u8>)> =
            encoders.iter().map(|(n, f)| (*n, f.as_ref() as _)).collect();
        self.session_exchange(&mut pool, ctx, &shards)
    }
}

impl AddressEngine for RemoteEngine {
    fn name(&self) -> &'static str {
        "remote"
    }

    /// The workers run the host engines, which serve every layout.
    fn supports(&self, _layout: &ArrayLayout) -> bool {
        true
    }

    fn translate(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        batch.check()?;
        if batch.is_empty() {
            out.clear();
            return Ok(());
        }
        let replies = self.map_request(Op::Translate, ctx, batch)?;
        // Decode into scratch first: `out` is only written once every
        // shard decoded and the lengths reconcile — never truncated.
        let mut spliced = BatchOut::new();
        for body in &replies {
            decode_batch_response(body, &mut spliced)?;
        }
        if spliced.len() != batch.len() {
            return Err(EngineError::Backend(format!(
                "remote: spliced {} results for a {}-request batch",
                spliced.len(),
                batch.len()
            )));
        }
        out.clear();
        out.append(&mut spliced);
        Ok(())
    }

    fn increment(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        batch.check()?;
        if batch.is_empty() {
            out.clear();
            return Ok(());
        }
        let replies = self.map_request(Op::Increment, ctx, batch)?;
        let mut spliced = Vec::new();
        for body in &replies {
            decode_ptrs_response(body, &mut spliced)?;
        }
        if spliced.len() != batch.len() {
            return Err(EngineError::Backend(format!(
                "remote: spliced {} results for a {}-request batch",
                spliced.len(),
                batch.len()
            )));
        }
        out.clear();
        out.append(&mut spliced);
        Ok(())
    }

    fn walk(
        &self,
        ctx: &EngineCtx,
        start: SharedPtr,
        inc: u64,
        steps: usize,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        if steps == 0 {
            out.clear();
            return Ok(());
        }
        let mut pool = self.lock_pool()?;
        self.ensure_pool(&mut pool)?;
        // Same overflow guard as the thread tier: shard origin offsets
        // never exceed inc·steps, so if that product overflows the walk
        // goes to one worker whole (whose engine then applies its own
        // stride-range check).
        let k = if inc.checked_mul(steps as u64).is_none() {
            1
        } else {
            self.fanout(steps, pool.len())
        };
        let chunk = steps.div_ceil(k);
        let mut encoders: Vec<(usize, Box<dyn Fn(u64) -> Vec<u8> + '_>)> =
            Vec::with_capacity(k);
        for i in 0..k {
            let lo = (i * chunk).min(steps);
            let hi = ((i + 1) * chunk).min(steps);
            // Shard i's origin is `lo` strides past `start`; one
            // general increment by lo·inc lands on the identical
            // pointer by the composition law.
            let shard_start =
                increment_general(&start, inc * lo as u64, ctx.layout());
            encoders.push((
                hi - lo,
                Box::new(move |epoch| {
                    encode_walk_request(epoch, shard_start, inc, (hi - lo) as u64)
                }),
            ));
        }
        let shards: Vec<(usize, &dyn Fn(u64) -> Vec<u8>)> =
            encoders.iter().map(|(n, f)| (*n, f.as_ref() as _)).collect();
        let replies = self.session_exchange(&mut pool, ctx, &shards)?;
        drop(pool);
        let mut spliced = BatchOut::new();
        for body in &replies {
            decode_batch_response(body, &mut spliced)?;
        }
        if spliced.len() != steps {
            return Err(EngineError::Backend(format!(
                "remote: spliced {} results for a {steps}-step walk",
                spliced.len()
            )));
        }
        out.clear();
        out.append(&mut spliced);
        Ok(())
    }

    fn translate_one(
        &self,
        ctx: &EngineCtx,
        ptr: SharedPtr,
        inc: u64,
    ) -> Result<(SharedPtr, u64, Locality), EngineError> {
        // One socket round-trip for one pointer: legal but never worth
        // it — the selector's `remote_threshold` keeps scalars off this
        // path.
        let mut batch = PtrBatch::with_capacity(1);
        batch.push(ptr, inc);
        let mut out = BatchOut::new();
        self.translate(ctx, &batch, &mut out)?;
        Ok((out.ptrs[0], out.sysva[0], out.loc[0]))
    }
}

impl Drop for RemoteEngine {
    fn drop(&mut self) {
        if let Ok(mut pool) = self.pool.lock() {
            for w in pool.iter_mut() {
                // Best-effort graceful session end, then (spawn mode)
                // the hammer — a wedged worker must not outlive its
                // supervisor.
                let _ =
                    write_frame(&mut w.stream, &encode_simple_request(Op::Shutdown));
                w.reap();
            }
            pool.clear();
        }
        if let WorkerMode::Spawn { dir, .. } = &self.mode {
            let _ = std::fs::remove_dir(dir);
        }
    }
}

/// A remote pool bundled with the pricing the selector should use for
/// it — what `Machine::install_remote`,
/// `coordinator::engine_report_with` and the CLI's `--remote`/`--daemon`
/// flags share, so every core/runtime prices the *same* pool with the
/// *same* measured legs (calibrating per core would spam round-trips).
#[derive(Clone)]
pub struct RemoteTier {
    pub engine: Arc<RemoteEngine>,
    /// Marginal cost per pointer through the pool (measured, or 0 for
    /// a forced tier).
    pub ns_per_ptr: f64,
    /// Fixed scatter/gather fee per request (measured, or 0).
    pub dispatch_ns: f64,
    /// Minimum batch size eligible for the remote leg of the argmin.
    pub threshold: usize,
}

impl RemoteTier {
    /// Spawn `workers` processes and **measure** the cost-model legs
    /// with [`RemoteEngine::calibrate`] — honest pricing: on a single
    /// host the socket hop rarely beats the in-process tiers, and the
    /// argmin will say so.
    pub fn spawn(workers: usize) -> Result<Self, EngineError> {
        Self::from_engine(Arc::new(RemoteEngine::spawn(workers)?), false)
    }

    /// Spawn a pool priced as if the service hop were free (zero legs,
    /// threshold 1, per-request fan-out): emulates the paper's thesis
    /// — a *dedicated* mapping unit behind a cheap interface — so
    /// demos, reports and the acceptance differentials can observe the
    /// remote tier actually serving traffic on one host.
    pub fn spawn_forced(workers: usize) -> Result<Self, EngineError> {
        Self::from_engine(
            Arc::new(RemoteEngine::spawn(workers)?.with_min_shard_len(1)),
            true,
        )
    }

    /// Connect `connections` sessions to a running `pgas-hw daemon`
    /// and measure the legs.  Daemon-served pricing uses the lower
    /// [`EngineSelector::DEFAULT_DAEMON_THRESHOLD`]: with epoch
    /// sessions the steady-state dispatch fee excludes the ctx
    /// snapshot, so smaller batches clear the bar.
    pub fn connect(
        socket: impl Into<PathBuf>,
        connections: usize,
    ) -> Result<Self, EngineError> {
        let engine = Arc::new(RemoteEngine::connect(socket, connections)?);
        let (ns_per_ptr, dispatch_ns) = engine.calibrate()?;
        Ok(Self {
            engine,
            ns_per_ptr,
            dispatch_ns,
            threshold: EngineSelector::DEFAULT_DAEMON_THRESHOLD,
        })
    }

    /// [`connect`](Self::connect) with forced zero-cost pricing (every
    /// eligible window takes the hop — demos and differentials).
    pub fn connect_forced(
        socket: impl Into<PathBuf>,
        connections: usize,
    ) -> Result<Self, EngineError> {
        Self::from_engine(
            Arc::new(RemoteEngine::connect(socket, connections)?.with_min_shard_len(1)),
            true,
        )
    }

    /// Wrap an already-built pool; `forced` picks the zero-cost
    /// pricing, otherwise the legs are measured now.
    pub fn from_engine(
        engine: Arc<RemoteEngine>,
        forced: bool,
    ) -> Result<Self, EngineError> {
        if forced {
            Ok(Self { engine, ns_per_ptr: 0.0, dispatch_ns: 0.0, threshold: 1 })
        } else {
            let (ns_per_ptr, dispatch_ns) = engine.calibrate()?;
            Ok(Self {
                engine,
                ns_per_ptr,
                dispatch_ns,
                threshold: EngineSelector::DEFAULT_REMOTE_THRESHOLD,
            })
        }
    }

    /// Install this tier (shared pool + its pricing) into a selector.
    pub fn apply(&self, sel: &mut EngineSelector) {
        sel.set_remote(
            Arc::clone(&self.engine),
            self.ns_per_ptr,
            self.dispatch_ns,
            self.threshold,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SoftwareEngine;
    use crate::sptr::Topology;

    /// Protocol tests run over a socketpair with `serve_session` on a
    /// thread — no processes, so they stay in the lib suite; the
    /// process-pool paths live in `rust/tests/remote_engine.rs` where
    /// `CARGO_BIN_EXE_pgas-hw` is available.
    fn with_loopback<R>(f: impl FnOnce(&mut UnixStream) -> R) -> R {
        let (mut client, mut server) =
            UnixStream::pair().expect("socketpair");
        let handle = std::thread::spawn(move || {
            let _ = serve_session(&mut server);
        });
        let r = f(&mut client);
        drop(client); // EOF ends the session thread
        handle.join().expect("serve_session thread");
        r
    }

    fn roundtrip(stream: &mut UnixStream, req: &[u8]) -> Vec<u8> {
        write_frame(stream, req).expect("send");
        read_frame(stream).expect("recv").expect("reply frame")
    }

    fn install(stream: &mut UnixStream, epoch: u64, ctx: &EngineCtx) {
        let reply =
            roundtrip(stream, &encode_install_request(epoch, false, ctx));
        open_response(&reply).expect("install ack");
    }

    #[test]
    fn translate_over_the_wire_matches_software() {
        let layout = ArrayLayout::new(3, 112, 5); // CG-style non-pow2
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 2)
            .unwrap()
            .with_topology(Topology {
                log2_threads_per_mc: 1,
                log2_threads_per_node: 3,
            });
        let mut batch = PtrBatch::new();
        for i in 0..97u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 7), i % 13);
        }
        let got = with_loopback(|s| {
            install(s, 7, &ctx);
            let req =
                encode_map_request(Op::Translate, 7, &batch.ptrs, &batch.incs);
            let reply = roundtrip(s, &req);
            let mut out = BatchOut::new();
            decode_batch_response(&reply, &mut out).unwrap();
            out
        });
        let mut want = BatchOut::new();
        SoftwareEngine.translate(&ctx, &batch, &mut want).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn walk_and_increment_reuse_one_installed_epoch() {
        let layout = ArrayLayout::new(8, 4, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 1).unwrap();
        let start = SharedPtr::for_index(&layout, 0, 5);
        let (walk_got, inc_got) = with_loopback(|s| {
            install(s, 42, &ctx);
            let reply = roundtrip(s, &encode_walk_request(42, start, 3, 41));
            let mut w = BatchOut::new();
            decode_batch_response(&reply, &mut w).unwrap();
            let mut batch = PtrBatch::new();
            for i in 0..33u64 {
                batch.push(SharedPtr::for_index(&layout, 0, i), i % 7);
            }
            // second op on the same epoch: no re-install needed
            let reply = roundtrip(
                s,
                &encode_map_request(Op::Increment, 42, &batch.ptrs, &batch.incs),
            );
            let mut p = Vec::new();
            decode_ptrs_response(&reply, &mut p).unwrap();
            (w, p)
        });
        let mut want_walk = BatchOut::new();
        SoftwareEngine.walk(&ctx, start, 3, 41, &mut want_walk).unwrap();
        assert_eq!(walk_got, want_walk);
        let mut batch = PtrBatch::new();
        for i in 0..33u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i), i % 7);
        }
        let mut want_inc = Vec::new();
        SoftwareEngine.increment(&ctx, &batch, &mut want_inc).unwrap();
        assert_eq!(inc_got, want_inc);
    }

    /// The acceptance-criteria frame-size assertion: once a ctx is
    /// installed, steady-state request frames carry **no** ctx snapshot
    /// — their size is exactly header + epoch + payload, independent of
    /// the base-table size, while the install frame grows with it.
    #[test]
    fn steady_state_frames_carry_no_ctx_snapshot() {
        const HEADER: usize = 4 + 2 + 1; // magic + version + op
        for threads in [4u32, 4096] {
            let layout = ArrayLayout::new(8, 8, threads);
            let table = BaseTable::regular(threads, 1 << 32, 1 << 32);
            let ctx = EngineCtx::new(layout, &table, 0).unwrap();
            let n = 257;
            let mut batch = PtrBatch::new();
            for i in 0..n as u64 {
                batch.push(SharedPtr::for_index(&layout, 0, i), i);
            }
            let map =
                encode_map_request(Op::Translate, 9, &batch.ptrs, &batch.incs);
            // epoch u64 + count u32 + n × (ptr 20 + inc 8): no layout,
            // no table, no topology — for ANY table size
            assert_eq!(map.len(), HEADER + 8 + 4 + n * 28);
            let walk = encode_walk_request(9, SharedPtr::NULL, 3, 100);
            assert_eq!(walk.len(), HEADER + 8 + 20 + 8 + 8);
            // whereas the install frame carries the full snapshot
            let install = encode_install_request(9, false, &ctx);
            assert_eq!(
                install.len(),
                HEADER + 8 + 1 + (20 + 4 + 8) + (4 + 8 * threads as usize)
            );
        }
    }

    #[test]
    fn unknown_epoch_draws_a_stale_reply_until_installed() {
        let layout = ArrayLayout::new(8, 4, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        batch.push(SharedPtr::NULL, 3);
        with_loopback(|s| {
            // no ctx installed yet: stale, with the distinct status
            let reply = roundtrip(
                s,
                &encode_map_request(Op::Increment, 5, &batch.ptrs, &batch.incs),
            );
            assert_eq!(body_status(&reply), Some(STATUS_STALE_EPOCH));
            let err = open_response(&reply).unwrap_err();
            assert!(err.to_string().contains("stale epoch"), "{err}");
            // install epoch 5, the same request now serves
            install(s, 5, &ctx);
            let reply = roundtrip(
                s,
                &encode_map_request(Op::Increment, 5, &batch.ptrs, &batch.incs),
            );
            assert_eq!(body_status(&reply), Some(STATUS_OK));
            // a different epoch is stale again (one epoch per session)
            let reply = roundtrip(
                s,
                &encode_map_request(Op::Increment, 6, &batch.ptrs, &batch.incs),
            );
            assert_eq!(body_status(&reply), Some(STATUS_STALE_EPOCH));
        });
    }

    #[test]
    fn version_and_magic_mismatches_error_loudly() {
        with_loopback(|s| {
            // wrong version
            let mut w = WireWriter::new();
            w.put_u32(MAGIC);
            w.put_u16(PROTOCOL_VERSION + 1);
            w.put_u8(Op::Ping as u8);
            let reply = roundtrip(s, w.bytes());
            let err = open_response(&reply).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("protocol"), "{msg}");
            // wrong magic: the worker answers an error frame rather
            // than dying, so the session survives for the next request
            let mut w = WireWriter::new();
            w.put_u32(0x1BADF00D);
            w.put_u16(PROTOCOL_VERSION);
            w.put_u8(Op::Ping as u8);
            let reply = roundtrip(s, w.bytes());
            assert!(open_response(&reply).is_err());
            // a well-formed ping still works on the same stream
            let reply = roundtrip(s, &encode_simple_request(Op::Ping));
            assert!(open_response(&reply).is_ok());
        });
    }

    #[test]
    fn shutdown_ends_the_session_with_an_ack() {
        let (mut client, mut server) = UnixStream::pair().expect("socketpair");
        let handle =
            std::thread::spawn(move || serve_session(&mut server));
        write_frame(&mut client, &encode_simple_request(Op::Shutdown)).unwrap();
        let reply = read_frame(&mut client).unwrap().expect("ack");
        assert!(open_response(&reply).is_ok());
        assert!(handle.join().unwrap().is_ok());
        // stream is now closed from the worker side
        assert!(matches!(read_frame(&mut client), Ok(None)));
    }

    #[test]
    fn oversized_frames_are_refused() {
        // hand-craft a header claiming u32::MAX body bytes
        let (mut tx, mut rx) = UnixStream::pair().expect("socketpair");
        tx.write_all(&u32::MAX.to_le_bytes()).expect("header write");
        let err = read_frame(&mut rx).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidData);
    }

    /// The gather planner's per-bucket ceiling is derived from this
    /// module's wire arithmetic: a bucket at `max_bucket_len()` still
    /// fits the reply frame cap, one more pointer would not — so a
    /// plan the inspector accepts can never produce the oversized
    /// frame `check_frame_budget` (and the worker, on receipt) would
    /// kill the request for.
    #[test]
    fn gather_bucket_cap_matches_the_wire_frame_budget() {
        use crate::engine::GatherPlan;
        let cap = GatherPlan::max_bucket_len();
        assert!(reply_frame_bytes(cap) <= MAX_FRAME);
        assert!(reply_frame_bytes(cap + 1) > MAX_FRAME);
        assert!(check_frame_budget(0, cap).is_ok());
        assert!(check_frame_budget(0, cap + 1).is_err());
    }

    /// A pathological server that acks installs but answers every op
    /// with a stale-epoch status forever: the client must burn its
    /// re-install budget and then fail loudly, not retry for eternity.
    #[test]
    fn repeated_stale_epochs_fail_loudly_after_the_reinstall_budget() {
        let socket = crate::daemon::scratch_socket("always-stale");
        let listener = UnixListener::bind(&socket).expect("bind scratch");
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().expect("accept");
            while let Ok(Some(frame)) = read_frame(&mut s) {
                let reply = if frame.get(6) == Some(&(Op::InstallCtx as u8)) {
                    ok_header().into_bytes()
                } else {
                    reply_status_body(STATUS_STALE_EPOCH, "never installs")
                };
                if write_frame(&mut s, &reply).is_err() {
                    break;
                }
            }
        });
        let engine = RemoteEngine::connect(&socket, 1).expect("connect");
        let layout = ArrayLayout::new(8, 4, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        batch.push(SharedPtr::NULL, 1);
        let mut out = Vec::new();
        let err = engine.increment(&ctx, &batch, &mut out).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("stale epoch") && msg.contains("re-install"),
            "{msg}"
        );
        assert_eq!(engine.stale_failures(), 1);
        assert_eq!(
            engine.reinstalls(),
            u64::from(RemoteEngine::MAX_STALE_REINSTALLS)
        );
        assert_eq!(engine.client_stats().stale_failures, 1);
        drop(engine);
        server.join().expect("server thread");
        let _ = std::fs::remove_file(&socket);
    }

    /// Injected frame corruption is a per-request fault: the server
    /// rejects the frame with an error reply, the request fails loudly,
    /// and the connection stays healthy — no heal, no reconnect.
    #[test]
    fn injected_frame_corruption_fails_loudly_but_the_session_survives() {
        use crate::engine::FaultSpec;
        let socket = crate::daemon::scratch_socket("chaos-wire");
        let listener = UnixListener::bind(&socket).expect("bind scratch");
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().expect("accept");
            let _ = serve_session(&mut s);
        });
        let plan = Arc::new(FaultPlan::new(FaultSpec {
            corrupt: 1.0,
            ..FaultSpec::quiet(11)
        }));
        let engine = RemoteEngine::connect(&socket, 1)
            .expect("connect")
            .with_chaos(Arc::clone(&plan));
        let layout = ArrayLayout::new(8, 4, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        batch.push(SharedPtr::NULL, 1);
        let mut out = Vec::new();
        for _ in 0..3 {
            let err = engine.increment(&ctx, &batch, &mut out).unwrap_err();
            assert!(matches!(err, EngineError::Backend(_)), "{err}");
        }
        assert_eq!(plan.wire_faults(), 3);
        assert_eq!(
            engine.reconnects(),
            0,
            "corrupt frames must not cost a heal"
        );
        drop(engine);
        server.join().expect("server thread");
        let _ = std::fs::remove_file(&socket);
    }
}
