//! The process tier: address mapping as a *service*.  A
//! [`RemoteEngine`] scatter/gathers [`PtrBatch`]es and walk step-ranges
//! across N worker **processes** speaking a length-prefixed binary
//! protocol over Unix-domain sockets — the scale-out seam the ROADMAP
//! kept open after the thread tier ([`ShardedEngine`](super::ShardedEngine))
//! landed: the same [`AddressEngine`] contract, served from outside the
//! client's address space.
//!
//! ## Protocol
//!
//! Every message is one *frame*: a little-endian `u32` byte length
//! followed by that many body bytes.  A body starts with a versioned
//! header (`MAGIC u32`, [`PROTOCOL_VERSION`] `u16`, op `u8`) so a
//! mismatched peer fails loudly instead of mis-decoding.  Requests
//! carry a full [`EngineCtx`] snapshot — layout, base table, executing
//! thread, topology — serialized with the checked
//! [`sptr::wire`](crate::sptr::WireWriter) helpers, then the op
//! payload:
//!
//! | op | request payload | ok-response payload |
//! |----|-----------------|---------------------|
//! | `Translate` | `n u32`, n×ptr, n×`u64` inc | `n u32`, n×ptr, n×`u64` sysva, n×`u8` loc |
//! | `Increment` | `n u32`, n×ptr, n×`u64` inc | `n u32`, n×ptr |
//! | `Walk`      | start ptr, `inc u64`, `steps u64` | as `Translate` |
//! | `Ping`      | —               | — (calibration round-trip) |
//! | `Shutdown`  | —               | — (worker exits after ack) |
//!
//! Responses echo the header with a status byte (0 = ok, 1 = error +
//! UTF-8 message).  Requests are **framed per shard**: a batch of `n`
//! requests fans out to `k = clamp(n / min_shard_len, 1, workers)`
//! contiguous shards, one frame to worker `i` per shard `i`, and the
//! replies are spliced back **in shard order** — the same
//! order-preserving splice as [`ShardedEngine`](super::ShardedEngine),
//! so output is bit-identical to the inner engine at any worker count
//! (`rust/tests/remote_engine.rs` pins this over the NPB layouts at
//! 1/2/4 workers).  Walks shard over the step range with
//! [`increment_general`] origin offsets, guarded by
//! `inc.checked_mul(steps)` exactly like the thread tier.
//!
//! ## Worker lifecycle & failure semantics
//!
//! [`RemoteEngine::spawn`] launches `pgas-hw serve-engine --socket S`
//! once per worker (binary resolution: `PGAS_HW_WORKER_BIN`, the
//! current executable when it *is* `pgas-hw`, else a `pgas-hw` sibling
//! of the current executable) and connects with a bounded retry loop.
//! Each worker serves exactly one client session with a per-request
//! [`AutoEngine`] and exits when the connection closes.
//!
//! Failure is never silent: connect timeouts, short reads, stalled
//! workers (socket read timeout) and worker death all surface as
//! [`EngineError::Backend`] naming the worker, the **in-flight request
//! fails loudly** (outputs are committed only after every shard reply
//! decodes and the total length equals the request length — a short
//! response can never be returned as a truncated success), and the
//! whole pool is restarted before the error returns so the next
//! request sees clean streams ([`RemoteEngine::restarts`] counts these
//! recoveries; `kill_worker` is the chaos hook the tests use).

use std::io::{ErrorKind, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::{
    AddressEngine, AutoEngine, BatchOut, EngineCtx, EngineError,
    EngineSelector, PtrBatch,
};
use crate::sptr::{
    increment_general, ArrayLayout, BaseTable, Locality, SharedPtr,
    WireReader, WireWriter,
};

/// Version of the frame format.  Bumped on any wire-shape change; the
/// worker refuses mismatched requests with a loud error naming both
/// versions.
pub const PROTOCOL_VERSION: u16 = 1;

/// "PGAS" — frame bodies open with this so a desynced or foreign peer
/// is detected immediately.
pub const MAGIC: u32 = 0x5047_4153;

/// Upper bound on one frame body; a corrupt length prefix must not OOM
/// the peer.
const MAX_FRAME: usize = 1 << 30;

/// Wire bytes of one batch-shaped result (ptr 20 + sysva 8 + loc 1).
const RESULT_WIRE_BYTES: usize = 29;

/// Conservative size of a reply frame carrying `n` batch-shaped
/// results (header + count + columns).
fn reply_frame_bytes(n: usize) -> usize {
    64 + n.saturating_mul(RESULT_WIRE_BYTES)
}

/// Refuse a shard whose request frame — or whose *reply* — would blow
/// the frame cap, before anything is sent: a too-large frame would
/// otherwise kill the worker on receipt (or on reply) and loop through
/// pool restarts without ever succeeding.
fn check_frame_budget(request_len: usize, results: usize) -> Result<(), EngineError> {
    if request_len > MAX_FRAME || reply_frame_bytes(results) > MAX_FRAME {
        return Err(EngineError::Backend(format!(
            "remote: a shard of {results} requests ({request_len}-byte frame) \
             would exceed the {MAX_FRAME}-byte frame cap; use more workers \
             or split the batch"
        )));
    }
    Ok(())
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Op {
    Translate = 0,
    Increment = 1,
    Walk = 2,
    Ping = 3,
    Shutdown = 4,
}

impl Op {
    fn from_u8(v: u8) -> Option<Op> {
        match v {
            0 => Some(Op::Translate),
            1 => Some(Op::Increment),
            2 => Some(Op::Walk),
            3 => Some(Op::Ping),
            4 => Some(Op::Shutdown),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------- frames

fn write_frame(stream: &mut UnixStream, body: &[u8]) -> std::io::Result<()> {
    let len = u32::try_from(body.len()).map_err(|_| {
        std::io::Error::new(ErrorKind::InvalidInput, "frame exceeds u32 length")
    })?;
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// Read one frame.  `Ok(None)` is a clean end-of-stream *at a frame
/// boundary* (the peer closed between requests); EOF mid-frame is a
/// short read and errors.
fn read_frame(stream: &mut UnixStream) -> std::io::Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    match stream.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Ok(Some(body))
}

// ------------------------------------------------------------- encoding

fn begin_body(op: Op) -> WireWriter {
    let mut w = WireWriter::new();
    w.put_u32(MAGIC);
    w.put_u16(PROTOCOL_VERSION);
    w.put_u8(op as u8);
    w
}

fn put_ctx(w: &mut WireWriter, ctx: &EngineCtx) {
    w.put_layout(ctx.layout());
    w.put_u32(ctx.mythread());
    w.put_topology(ctx.topo());
    w.put_table(ctx.table());
}

fn encode_map_request(
    op: Op,
    ctx: &EngineCtx,
    ptrs: &[SharedPtr],
    incs: &[u64],
) -> Vec<u8> {
    let mut w = begin_body(op);
    put_ctx(&mut w, ctx);
    w.put_u32(ptrs.len() as u32);
    for p in ptrs {
        w.put_ptr(p);
    }
    for &i in incs {
        w.put_u64(i);
    }
    w.into_bytes()
}

fn encode_walk_request(
    ctx: &EngineCtx,
    start: SharedPtr,
    inc: u64,
    steps: u64,
) -> Vec<u8> {
    let mut w = begin_body(Op::Walk);
    put_ctx(&mut w, ctx);
    w.put_ptr(&start);
    w.put_u64(inc);
    w.put_u64(steps);
    w.into_bytes()
}

fn encode_simple_request(op: Op) -> Vec<u8> {
    begin_body(op).into_bytes()
}

fn ok_header() -> WireWriter {
    let mut w = WireWriter::new();
    w.put_u32(MAGIC);
    w.put_u16(PROTOCOL_VERSION);
    w.put_u8(0); // status ok
    w
}

fn error_body(msg: &str) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.put_u32(MAGIC);
    w.put_u16(PROTOCOL_VERSION);
    w.put_u8(1); // status error
    let bytes = msg.as_bytes();
    w.put_u32(bytes.len() as u32);
    w.put_bytes(bytes);
    w.into_bytes()
}

fn encode_batch_out(w: &mut WireWriter, out: &BatchOut) {
    w.put_u32(out.len() as u32);
    for p in &out.ptrs {
        w.put_ptr(p);
    }
    for &s in &out.sysva {
        w.put_u64(s);
    }
    for &l in &out.loc {
        w.put_locality(l);
    }
}

// ------------------------------------------------------------- decoding

/// Check a response header; on error status, surface the worker's
/// message.  Returns a reader positioned at the payload.
fn open_response(body: &[u8]) -> Result<WireReader<'_>, EngineError> {
    let mut r = WireReader::new(body);
    let backend = EngineError::Backend;
    let magic = r.get_u32().map_err(|e| backend(format!("remote: {e}")))?;
    if magic != MAGIC {
        return Err(backend(format!(
            "remote: response magic {magic:#x} != {MAGIC:#x} (desynced stream?)"
        )));
    }
    let version = r.get_u16().map_err(|e| backend(format!("remote: {e}")))?;
    if version != PROTOCOL_VERSION {
        return Err(backend(format!(
            "remote: worker speaks protocol v{version}, client v{PROTOCOL_VERSION}"
        )));
    }
    let status = r.get_u8().map_err(|e| backend(format!("remote: {e}")))?;
    if status != 0 {
        let n = r.get_count(1).map_err(|e| backend(format!("remote: {e}")))?;
        let msg = r.get_bytes(n).map_err(|e| backend(format!("remote: {e}")))?;
        let msg = String::from_utf8_lossy(msg);
        return Err(backend(format!("remote: worker error: {msg}")));
    }
    Ok(r)
}

fn decode_batch_response(body: &[u8], into: &mut BatchOut) -> Result<(), EngineError> {
    let mut r = open_response(body)?;
    let wire = |e: crate::sptr::WireError| {
        EngineError::Backend(format!("remote: malformed response: {e}"))
    };
    // count validated against the frame before any reserve sized by it
    let n = r.get_count(RESULT_WIRE_BYTES).map_err(wire)?;
    into.reserve(n);
    let base = into.ptrs.len();
    for _ in 0..n {
        let p = r.get_ptr().map_err(wire)?;
        into.ptrs.push(p);
    }
    for _ in 0..n {
        into.sysva.push(r.get_u64().map_err(wire)?);
    }
    for _ in 0..n {
        into.loc.push(r.get_locality().map_err(wire)?);
    }
    debug_assert_eq!(into.ptrs.len(), base + n);
    r.finish().map_err(wire)
}

fn decode_ptrs_response(
    body: &[u8],
    into: &mut Vec<SharedPtr>,
) -> Result<(), EngineError> {
    let mut r = open_response(body)?;
    let wire = |e: crate::sptr::WireError| {
        EngineError::Backend(format!("remote: malformed response: {e}"))
    };
    let n = r.get_count(20).map_err(wire)?; // 20 = wire bytes per ptr
    into.reserve(n);
    for _ in 0..n {
        into.push(r.get_ptr().map_err(wire)?);
    }
    r.finish().map_err(wire)
}

// ------------------------------------------------------- worker (server)

/// Decode and serve one request frame with a per-request [`AutoEngine`].
/// Returns the response body and whether the session should end.
fn handle_frame(frame: &[u8]) -> (Vec<u8>, bool) {
    match try_handle(frame) {
        Ok(reply) => reply,
        Err(msg) => (error_body(&msg), false),
    }
}

fn try_handle(frame: &[u8]) -> Result<(Vec<u8>, bool), String> {
    let mut r = WireReader::new(frame);
    let magic = r.get_u32().map_err(|e| e.to_string())?;
    if magic != MAGIC {
        return Err(format!("request magic {magic:#x} != {MAGIC:#x}"));
    }
    let version = r.get_u16().map_err(|e| e.to_string())?;
    if version != PROTOCOL_VERSION {
        return Err(format!(
            "client speaks protocol v{version}, worker v{PROTOCOL_VERSION}"
        ));
    }
    let op = Op::from_u8(r.get_u8().map_err(|e| e.to_string())?)
        .ok_or_else(|| "unknown op".to_string())?;
    match op {
        Op::Ping => Ok((ok_header().into_bytes(), false)),
        Op::Shutdown => Ok((ok_header().into_bytes(), true)),
        Op::Translate | Op::Increment => {
            let (layout, mythread, topo, table) = get_ctx(&mut r)?;
            // 28 = ptr 20 + inc 8: bound the allocation by the frame
            let n = r.get_count(28).map_err(|e| e.to_string())?;
            // replies are wider than requests (29 B/result vs 28), so
            // a near-cap request could produce an over-cap reply —
            // refuse here like the walk path does, a loud worker-side
            // error instead of a desynced oversized reply frame
            if reply_frame_bytes(n) > MAX_FRAME {
                return Err(format!(
                    "batch of {n} requests would exceed the reply frame cap"
                ));
            }
            let mut batch = PtrBatch::with_capacity(n);
            for _ in 0..n {
                batch.ptrs.push(r.get_ptr().map_err(|e| e.to_string())?);
            }
            for _ in 0..n {
                batch.incs.push(r.get_u64().map_err(|e| e.to_string())?);
            }
            r.finish().map_err(|e| e.to_string())?;
            let ctx = EngineCtx::new(layout, &table, mythread)
                .map_err(|e| e.to_string())?
                .with_topology(topo);
            if op == Op::Translate {
                let mut out = BatchOut::new();
                AutoEngine
                    .translate(&ctx, &batch, &mut out)
                    .map_err(|e| e.to_string())?;
                let mut w = ok_header();
                encode_batch_out(&mut w, &out);
                Ok((w.into_bytes(), false))
            } else {
                let mut out = Vec::new();
                AutoEngine
                    .increment(&ctx, &batch, &mut out)
                    .map_err(|e| e.to_string())?;
                let mut w = ok_header();
                w.put_u32(out.len() as u32);
                for p in &out {
                    w.put_ptr(p);
                }
                Ok((w.into_bytes(), false))
            }
        }
        Op::Walk => {
            let (layout, mythread, topo, table) = get_ctx(&mut r)?;
            let start = r.get_ptr().map_err(|e| e.to_string())?;
            let inc = r.get_u64().map_err(|e| e.to_string())?;
            let steps = r.get_u64().map_err(|e| e.to_string())?;
            r.finish().map_err(|e| e.to_string())?;
            let steps = usize::try_from(steps)
                .map_err(|_| "walk steps exceed usize".to_string())?;
            // the reply must fit one frame; refuse before allocating
            // `steps` results (also guards hand-written clients)
            if reply_frame_bytes(steps) > MAX_FRAME {
                return Err(format!(
                    "walk of {steps} steps would exceed the frame cap"
                ));
            }
            let ctx = EngineCtx::new(layout, &table, mythread)
                .map_err(|e| e.to_string())?
                .with_topology(topo);
            let mut out = BatchOut::new();
            AutoEngine
                .walk(&ctx, start, inc, steps, &mut out)
                .map_err(|e| e.to_string())?;
            let mut w = ok_header();
            encode_batch_out(&mut w, &out);
            Ok((w.into_bytes(), false))
        }
    }
}

type CtxParts = (ArrayLayout, u32, crate::sptr::Topology, BaseTable);

fn get_ctx(r: &mut WireReader<'_>) -> Result<CtxParts, String> {
    let layout = r.get_layout().map_err(|e| e.to_string())?;
    let mythread = r.get_u32().map_err(|e| e.to_string())?;
    let topo = r.get_topology().map_err(|e| e.to_string())?;
    let table = r.get_table().map_err(|e| e.to_string())?;
    Ok((layout, mythread, topo, table))
}

/// One client session on an established stream: loop
/// read-frame/serve/write-frame until the client disconnects or sends
/// `Shutdown`.  Split out so the protocol is unit-testable over a
/// socketpair without spawning processes.
fn serve_session(stream: &mut UnixStream) -> Result<(), String> {
    loop {
        let frame = match read_frame(stream) {
            Ok(Some(f)) => f,
            // Clean disconnect at a frame boundary: the supervising
            // client is gone, this worker's job is done.
            Ok(None) => return Ok(()),
            Err(e) => return Err(format!("serve-engine: read: {e}")),
        };
        let (reply, shutdown) = handle_frame(&frame);
        write_frame(stream, &reply)
            .map_err(|e| format!("serve-engine: write: {e}"))?;
        if shutdown {
            return Ok(());
        }
    }
}

/// The worker side of the remote tier — what `pgas-hw serve-engine
/// --socket PATH` runs: bind `socket`, accept exactly **one** client
/// session, serve it to completion, clean up, exit.  The supervising
/// [`RemoteEngine`] owns the process lifetime; a fresh worker gets a
/// fresh socket, so a lingering process can never serve a stale path.
pub fn serve(socket: &Path) -> Result<(), String> {
    let _ = std::fs::remove_file(socket);
    let listener = UnixListener::bind(socket)
        .map_err(|e| format!("serve-engine: bind {}: {e}", socket.display()))?;
    let (mut stream, _) = listener
        .accept()
        .map_err(|e| format!("serve-engine: accept: {e}"))?;
    let result = serve_session(&mut stream);
    let _ = std::fs::remove_file(socket);
    result
}

// ------------------------------------------------------- client (engine)

struct Worker {
    child: Child,
    stream: UnixStream,
    socket: PathBuf,
}

impl Worker {
    fn reap(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
        let _ = std::fs::remove_file(&self.socket);
    }
}

/// Resolve the worker executable: explicit env override, the current
/// executable when it *is* the CLI, else a `pgas-hw` next to (or one
/// directory above — test binaries live in `target/*/deps/`) the
/// current executable.
fn resolve_worker_bin() -> Result<PathBuf, EngineError> {
    if let Some(p) = std::env::var_os("PGAS_HW_WORKER_BIN") {
        return Ok(PathBuf::from(p));
    }
    let exe = std::env::current_exe().map_err(|e| {
        EngineError::Backend(format!("remote: cannot resolve current exe: {e}"))
    })?;
    if exe.file_stem().is_some_and(|s| s == "pgas-hw") {
        return Ok(exe);
    }
    let mut dirs: Vec<&Path> = Vec::new();
    if let Some(d) = exe.parent() {
        dirs.push(d);
        if let Some(p) = d.parent() {
            dirs.push(p);
        }
    }
    for d in dirs {
        let cand = d.join("pgas-hw");
        if cand.is_file() {
            return Ok(cand);
        }
    }
    Err(EngineError::Backend(
        "remote: cannot locate the `pgas-hw` worker binary; set \
         PGAS_HW_WORKER_BIN or use RemoteEngine::spawn_with_bin"
            .into(),
    ))
}

/// Process-pool backend: the same scatter/gather + order-preserving
/// splice as [`ShardedEngine`](super::ShardedEngine), over worker
/// *processes* instead of threads.  See the module docs for the
/// protocol and failure semantics.
pub struct RemoteEngine {
    /// One mutex over the whole pool: a request owns every stream it
    /// scatters to until the gather completes, so streams can never
    /// interleave frames from two requests.
    pool: Mutex<Vec<Worker>>,
    /// Configured pool size; the live pool can be smaller (empty)
    /// after a failed restart, and is re-grown to this target by
    /// `ensure_pool` on the next request.
    target_workers: usize,
    bin: PathBuf,
    dir: PathBuf,
    min_shard_len: usize,
    timeout: Duration,
    /// Monotonic worker generation — keeps respawned socket names
    /// unique.
    generation: AtomicU64,
    /// Pool restarts after a mid-request failure (telemetry; the
    /// worker-death tests assert recovery happened).
    restarts: AtomicU64,
}

impl RemoteEngine {
    /// Below this many requests per shard the serialization + socket
    /// hop cannot pay for itself; smaller batches go to worker 0 whole.
    pub const DEFAULT_MIN_SHARD_LEN: usize = 4096;

    /// Per-I/O timeout: a worker that neither answers nor dies within
    /// this window is treated as dead (stalls must not hang the
    /// client).
    pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(10);

    /// Spawn `workers` worker processes (clamped to ≥ 1) running the
    /// auto-resolved `pgas-hw` binary's `serve-engine` subcommand.
    pub fn spawn(workers: usize) -> Result<Self, EngineError> {
        Self::spawn_with_bin(resolve_worker_bin()?, workers)
    }

    /// [`spawn`](Self::spawn) with an explicit worker executable (the
    /// integration tests pass `env!("CARGO_BIN_EXE_pgas-hw")`).
    pub fn spawn_with_bin(
        bin: impl Into<PathBuf>,
        workers: usize,
    ) -> Result<Self, EngineError> {
        let workers = workers.max(1);
        let dir = std::env::temp_dir().join(format!(
            "pgas-hw-remote-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0)
        ));
        std::fs::create_dir_all(&dir).map_err(|e| {
            EngineError::Backend(format!(
                "remote: cannot create socket dir {}: {e}",
                dir.display()
            ))
        })?;
        let engine = Self {
            pool: Mutex::new(Vec::with_capacity(workers)),
            target_workers: workers,
            bin: bin.into(),
            dir,
            min_shard_len: Self::DEFAULT_MIN_SHARD_LEN,
            timeout: Self::DEFAULT_TIMEOUT,
            generation: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
        };
        {
            let mut pool = engine.pool.lock().expect("fresh mutex");
            engine.ensure_pool(&mut pool)?;
        }
        Ok(engine)
    }

    /// Override the inline-serve threshold (the conformance tests set 1
    /// to force real multi-worker fan-out on small batches).
    pub fn with_min_shard_len(mut self, n: usize) -> Self {
        self.min_shard_len = n.max(1);
        self
    }

    /// Override the per-I/O timeout.
    pub fn with_timeout(mut self, t: Duration) -> Self {
        self.timeout = t;
        self
    }

    /// Worker-pool size.
    pub fn workers(&self) -> usize {
        self.pool.lock().map(|p| p.len()).unwrap_or(0)
    }

    /// Pool restarts performed after mid-request worker failures.
    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    /// Chaos hook (tests/ops): force-kill worker `slot`'s process
    /// without telling the client side.  The next request touching the
    /// dead stream must fail loudly and restart the pool.
    pub fn kill_worker(&self, slot: usize) -> Result<(), EngineError> {
        let mut pool = self.lock_pool()?;
        let w = pool.get_mut(slot).ok_or_else(|| {
            EngineError::Backend(format!("remote: no worker slot {slot}"))
        })?;
        let _ = w.child.kill();
        let _ = w.child.wait();
        Ok(())
    }

    fn lock_pool(&self) -> Result<std::sync::MutexGuard<'_, Vec<Worker>>, EngineError> {
        self.pool.lock().map_err(|_| {
            EngineError::Backend("remote: pool mutex poisoned".into())
        })
    }

    fn spawn_worker(&self, slot: usize) -> Result<Worker, EngineError> {
        let generation = self.generation.fetch_add(1, Ordering::Relaxed);
        let socket = self.dir.join(format!("w{slot}-g{generation}.sock"));
        // stderr stays inherited: a crashing worker must be loud.
        let mut child = Command::new(&self.bin)
            .arg("serve-engine")
            .arg("--socket")
            .arg(&socket)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .spawn()
            .map_err(|e| {
                EngineError::Backend(format!(
                    "remote: cannot spawn worker {slot} ({}): {e}",
                    self.bin.display()
                ))
            })?;
        // Connect with a bounded retry loop: the worker needs a moment
        // to bind its socket; a worker that exits during startup is
        // reported with its status instead of a bare timeout.
        let deadline = Instant::now() + self.timeout;
        let stream = loop {
            match UnixStream::connect(&socket) {
                Ok(s) => break s,
                Err(connect_err) => {
                    if let Ok(Some(status)) = child.try_wait() {
                        return Err(EngineError::Backend(format!(
                            "remote: worker {slot} exited during startup \
                             ({status})"
                        )));
                    }
                    if Instant::now() >= deadline {
                        let _ = child.kill();
                        let _ = child.wait();
                        return Err(EngineError::Backend(format!(
                            "remote: worker {slot} did not accept on {} \
                             within {:?}: {connect_err}",
                            socket.display(),
                            self.timeout
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        };
        for (what, res) in [
            ("read", stream.set_read_timeout(Some(self.timeout))),
            ("write", stream.set_write_timeout(Some(self.timeout))),
        ] {
            res.map_err(|e| {
                EngineError::Backend(format!(
                    "remote: worker {slot}: set {what} timeout: {e}"
                ))
            })?;
        }
        Ok(Worker { child, stream, socket })
    }

    /// How many shards a request of `n` items fans out to.
    fn fanout(&self, n: usize, workers: usize) -> usize {
        (n / self.min_shard_len).clamp(1, workers.max(1))
    }

    /// Grow the pool back to its configured size (no-op when full).
    /// On a spawn failure everything spawned so far is reaped and the
    /// pool left **empty** — never short — so a later request heals or
    /// errors loudly here instead of indexing past the pool.
    fn ensure_pool(&self, pool: &mut Vec<Worker>) -> Result<(), EngineError> {
        while pool.len() < self.target_workers {
            match self.spawn_worker(pool.len()) {
                Ok(w) => pool.push(w),
                Err(e) => {
                    for w in pool.iter_mut() {
                        w.reap();
                    }
                    pool.clear();
                    return Err(EngineError::Backend(format!(
                        "remote: cannot (re)build the worker pool: {e}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Send `frames[i]` to worker `i` and collect the replies in shard
    /// order.  On any failure the in-flight request is abandoned, the
    /// **whole pool is restarted** (surviving workers may hold
    /// half-consumed streams — a respawn is the only state we can
    /// trust), and a loud error names the failed worker.
    fn scatter_gather(
        &self,
        pool: &mut Vec<Worker>,
        frames: &[Vec<u8>],
    ) -> Result<Vec<Vec<u8>>, EngineError> {
        debug_assert!(frames.len() <= pool.len());
        let mut failure: Option<(usize, String)> = None;
        for (i, frame) in frames.iter().enumerate() {
            if let Err(e) = write_frame(&mut pool[i].stream, frame) {
                failure = Some((i, format!("send: {e}")));
                break;
            }
        }
        let mut replies = Vec::with_capacity(frames.len());
        if failure.is_none() {
            for (i, _) in frames.iter().enumerate() {
                match read_frame(&mut pool[i].stream) {
                    Ok(Some(r)) => replies.push(r),
                    Ok(None) => {
                        failure = Some((i, "worker closed mid-request".into()));
                        break;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock
                        || e.kind() == ErrorKind::TimedOut =>
                    {
                        failure =
                            Some((i, format!("timed out after {:?}", self.timeout)));
                        break;
                    }
                    Err(e) => {
                        failure = Some((i, format!("recv: {e}")));
                        break;
                    }
                }
            }
        }
        if let Some((slot, what)) = failure {
            let n = pool.len();
            for w in pool.iter_mut() {
                w.reap();
            }
            pool.clear();
            self.restarts.fetch_add(1, Ordering::Relaxed);
            // Best-effort rebuild; if it fails too the pool stays
            // empty and the *next* request's `ensure_pool` retries (or
            // errors loudly) — it is never left short.
            let rebuilt = match self.ensure_pool(pool) {
                Ok(()) => format!("pool of {n} restarted"),
                Err(e) => format!("pool restart also failed ({e})"),
            };
            return Err(EngineError::Backend(format!(
                "remote: worker {slot} failed mid-request ({what}); request \
                 NOT served, {rebuilt}"
            )));
        }
        Ok(replies)
    }

    /// Measure this pool's cost-model legs with real round-trips:
    /// `dispatch_ns` is the best of 8 pings (pure frame + socket + op
    /// overhead), `ns_per_ptr` the marginal per-pointer cost of a
    /// pool-wide increment batch.  Returns `(ns_per_ptr, dispatch_ns)`
    /// — the same shape as `Leon3Engine::calibrate`.
    pub fn calibrate(&self) -> Result<(f64, f64), EngineError> {
        let mut dispatch_ns = f64::MAX;
        for _ in 0..8 {
            let t0 = Instant::now();
            self.ping()?;
            dispatch_ns = dispatch_ns.min(t0.elapsed().as_nanos() as f64);
        }
        // A batch wide enough to fan out over every worker.
        let n = self.min_shard_len.max(1024) * self.workers();
        let layout = ArrayLayout::new(64, 8, 16);
        let table = BaseTable::regular(16, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).expect("table covers layout");
        let mut batch = PtrBatch::with_capacity(n);
        for i in 0..n as u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 3), i % 4096);
        }
        let mut out = Vec::new();
        let mut best_ns = f64::MAX;
        for _ in 0..3 {
            let t0 = Instant::now();
            self.increment(&ctx, &batch, &mut out)?;
            best_ns = best_ns.min(t0.elapsed().as_nanos() as f64);
        }
        let ns_per_ptr = ((best_ns - dispatch_ns).max(0.0) / n as f64).max(0.05);
        Ok((ns_per_ptr, dispatch_ns))
    }

    /// One empty round-trip to worker 0 (liveness + dispatch cost).
    pub fn ping(&self) -> Result<(), EngineError> {
        let mut pool = self.lock_pool()?;
        self.ensure_pool(&mut pool)?;
        let frames = [encode_simple_request(Op::Ping)];
        let replies = self.scatter_gather(&mut pool, &frames)?;
        open_response(&replies[0]).map(|_| ())
    }

    /// Shared map-request path for translate/increment.
    fn map_request(
        &self,
        op: Op,
        ctx: &EngineCtx,
        batch: &PtrBatch,
    ) -> Result<Vec<Vec<u8>>, EngineError> {
        let mut pool = self.lock_pool()?;
        self.ensure_pool(&mut pool)?;
        let k = self.fanout(batch.len(), pool.len());
        let chunk = batch.len().div_ceil(k);
        let mut frames = Vec::with_capacity(k);
        for i in 0..k {
            // Clamp both bounds: ceil-sized chunks can exhaust the
            // batch before the last shard, leaving a legal empty range.
            let lo = (i * chunk).min(batch.len());
            let hi = ((i + 1) * chunk).min(batch.len());
            let frame = encode_map_request(
                op,
                ctx,
                &batch.ptrs[lo..hi],
                &batch.incs[lo..hi],
            );
            check_frame_budget(frame.len(), hi - lo)?;
            frames.push(frame);
        }
        self.scatter_gather(&mut pool, &frames)
    }
}

impl AddressEngine for RemoteEngine {
    fn name(&self) -> &'static str {
        "remote"
    }

    /// The workers run [`AutoEngine`], which serves every layout.
    fn supports(&self, _layout: &ArrayLayout) -> bool {
        true
    }

    fn translate(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        batch.check()?;
        if batch.is_empty() {
            out.clear();
            return Ok(());
        }
        let replies = self.map_request(Op::Translate, ctx, batch)?;
        // Decode into scratch first: `out` is only written once every
        // shard decoded and the lengths reconcile — never truncated.
        let mut spliced = BatchOut::new();
        for body in &replies {
            decode_batch_response(body, &mut spliced)?;
        }
        if spliced.len() != batch.len() {
            return Err(EngineError::Backend(format!(
                "remote: spliced {} results for a {}-request batch",
                spliced.len(),
                batch.len()
            )));
        }
        out.clear();
        out.append(&mut spliced);
        Ok(())
    }

    fn increment(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        batch.check()?;
        if batch.is_empty() {
            out.clear();
            return Ok(());
        }
        let replies = self.map_request(Op::Increment, ctx, batch)?;
        let mut spliced = Vec::new();
        for body in &replies {
            decode_ptrs_response(body, &mut spliced)?;
        }
        if spliced.len() != batch.len() {
            return Err(EngineError::Backend(format!(
                "remote: spliced {} results for a {}-request batch",
                spliced.len(),
                batch.len()
            )));
        }
        out.clear();
        out.append(&mut spliced);
        Ok(())
    }

    fn walk(
        &self,
        ctx: &EngineCtx,
        start: SharedPtr,
        inc: u64,
        steps: usize,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        if steps == 0 {
            out.clear();
            return Ok(());
        }
        let mut pool = self.lock_pool()?;
        self.ensure_pool(&mut pool)?;
        // Same overflow guard as the thread tier: shard origin offsets
        // never exceed inc·steps, so if that product overflows the walk
        // goes to one worker whole (whose engine then applies its own
        // stride-range check).
        let k = if inc.checked_mul(steps as u64).is_none() {
            1
        } else {
            self.fanout(steps, pool.len())
        };
        let chunk = steps.div_ceil(k);
        let mut frames = Vec::with_capacity(k);
        for i in 0..k {
            let lo = (i * chunk).min(steps);
            let hi = ((i + 1) * chunk).min(steps);
            // Shard i's origin is `lo` strides past `start`; one
            // general increment by lo·inc lands on the identical
            // pointer by the composition law.
            let shard_start =
                increment_general(&start, inc * lo as u64, ctx.layout());
            let frame =
                encode_walk_request(ctx, shard_start, inc, (hi - lo) as u64);
            check_frame_budget(frame.len(), hi - lo)?;
            frames.push(frame);
        }
        let replies = self.scatter_gather(&mut pool, &frames)?;
        drop(pool);
        let mut spliced = BatchOut::new();
        for body in &replies {
            decode_batch_response(body, &mut spliced)?;
        }
        if spliced.len() != steps {
            return Err(EngineError::Backend(format!(
                "remote: spliced {} results for a {steps}-step walk",
                spliced.len()
            )));
        }
        out.clear();
        out.append(&mut spliced);
        Ok(())
    }

    fn translate_one(
        &self,
        ctx: &EngineCtx,
        ptr: SharedPtr,
        inc: u64,
    ) -> Result<(SharedPtr, u64, Locality), EngineError> {
        // One socket round-trip for one pointer: legal but never worth
        // it — the selector's `remote_threshold` keeps scalars off this
        // path.
        let mut batch = PtrBatch::with_capacity(1);
        batch.push(ptr, inc);
        let mut out = BatchOut::new();
        self.translate(ctx, &batch, &mut out)?;
        Ok((out.ptrs[0], out.sysva[0], out.loc[0]))
    }
}

impl Drop for RemoteEngine {
    fn drop(&mut self) {
        if let Ok(mut pool) = self.pool.lock() {
            for w in pool.iter_mut() {
                // Best-effort graceful shutdown, then the hammer — a
                // wedged worker must not outlive its supervisor.
                let _ =
                    write_frame(&mut w.stream, &encode_simple_request(Op::Shutdown));
                w.reap();
            }
            pool.clear();
        }
        let _ = std::fs::remove_dir(&self.dir);
    }
}

/// A spawned remote pool bundled with the pricing the selector should
/// use for it — what `Machine::install_remote`,
/// `coordinator::engine_report_with` and the CLI's `--remote` flags
/// share, so every core/runtime prices the *same* pool with the *same*
/// measured legs (calibrating per core would spam round-trips).
#[derive(Clone)]
pub struct RemoteTier {
    pub engine: Arc<RemoteEngine>,
    /// Marginal cost per pointer through the pool (measured, or 0 for
    /// a forced tier).
    pub ns_per_ptr: f64,
    /// Fixed scatter/gather fee per request (measured, or 0).
    pub dispatch_ns: f64,
    /// Minimum batch size eligible for the remote leg of the argmin.
    pub threshold: usize,
}

impl RemoteTier {
    /// Spawn `workers` processes and **measure** the cost-model legs
    /// with [`RemoteEngine::calibrate`] — honest pricing: on a single
    /// host the socket hop rarely beats the in-process tiers, and the
    /// argmin will say so.
    pub fn spawn(workers: usize) -> Result<Self, EngineError> {
        Self::from_engine(Arc::new(RemoteEngine::spawn(workers)?), false)
    }

    /// Spawn a pool priced as if the service hop were free (zero legs,
    /// threshold 1, per-request fan-out): emulates the paper's thesis
    /// — a *dedicated* mapping unit behind a cheap interface — so
    /// demos, reports and the acceptance differentials can observe the
    /// remote tier actually serving traffic on one host.
    pub fn spawn_forced(workers: usize) -> Result<Self, EngineError> {
        Self::from_engine(
            Arc::new(RemoteEngine::spawn(workers)?.with_min_shard_len(1)),
            true,
        )
    }

    /// Wrap an already-spawned pool; `forced` picks the zero-cost
    /// pricing, otherwise the legs are measured now.
    pub fn from_engine(
        engine: Arc<RemoteEngine>,
        forced: bool,
    ) -> Result<Self, EngineError> {
        if forced {
            Ok(Self { engine, ns_per_ptr: 0.0, dispatch_ns: 0.0, threshold: 1 })
        } else {
            let (ns_per_ptr, dispatch_ns) = engine.calibrate()?;
            Ok(Self {
                engine,
                ns_per_ptr,
                dispatch_ns,
                threshold: EngineSelector::DEFAULT_REMOTE_THRESHOLD,
            })
        }
    }

    /// Install this tier (shared pool + its pricing) into a selector.
    pub fn apply(&self, sel: &mut EngineSelector) {
        sel.set_remote(
            Arc::clone(&self.engine),
            self.ns_per_ptr,
            self.dispatch_ns,
            self.threshold,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SoftwareEngine;
    use crate::sptr::Topology;

    /// Protocol tests run over a socketpair with `serve_session` on a
    /// thread — no processes, so they stay in the lib suite; the
    /// process-pool paths live in `rust/tests/remote_engine.rs` where
    /// `CARGO_BIN_EXE_pgas-hw` is available.
    fn with_loopback<R>(f: impl FnOnce(&mut UnixStream) -> R) -> R {
        let (mut client, mut server) =
            UnixStream::pair().expect("socketpair");
        let handle = std::thread::spawn(move || {
            let _ = serve_session(&mut server);
        });
        let r = f(&mut client);
        drop(client); // EOF ends the session thread
        handle.join().expect("serve_session thread");
        r
    }

    fn roundtrip(stream: &mut UnixStream, req: &[u8]) -> Vec<u8> {
        write_frame(stream, req).expect("send");
        read_frame(stream).expect("recv").expect("reply frame")
    }

    #[test]
    fn translate_over_the_wire_matches_software() {
        let layout = ArrayLayout::new(3, 112, 5); // CG-style non-pow2
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 2)
            .unwrap()
            .with_topology(Topology {
                log2_threads_per_mc: 1,
                log2_threads_per_node: 3,
            });
        let mut batch = PtrBatch::new();
        for i in 0..97u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 7), i % 13);
        }
        let got = with_loopback(|s| {
            let req = encode_map_request(
                Op::Translate,
                &ctx,
                &batch.ptrs,
                &batch.incs,
            );
            let reply = roundtrip(s, &req);
            let mut out = BatchOut::new();
            decode_batch_response(&reply, &mut out).unwrap();
            out
        });
        let mut want = BatchOut::new();
        SoftwareEngine.translate(&ctx, &batch, &mut want).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn walk_and_increment_round_trip() {
        let layout = ArrayLayout::new(8, 4, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 1).unwrap();
        let start = SharedPtr::for_index(&layout, 0, 5);
        let (walk_got, inc_got) = with_loopback(|s| {
            let reply = roundtrip(s, &encode_walk_request(&ctx, start, 3, 41));
            let mut w = BatchOut::new();
            decode_batch_response(&reply, &mut w).unwrap();
            let mut batch = PtrBatch::new();
            for i in 0..33u64 {
                batch.push(SharedPtr::for_index(&layout, 0, i), i % 7);
            }
            let reply = roundtrip(
                s,
                &encode_map_request(Op::Increment, &ctx, &batch.ptrs, &batch.incs),
            );
            let mut p = Vec::new();
            decode_ptrs_response(&reply, &mut p).unwrap();
            (w, p)
        });
        let mut want_walk = BatchOut::new();
        SoftwareEngine.walk(&ctx, start, 3, 41, &mut want_walk).unwrap();
        assert_eq!(walk_got, want_walk);
        let mut batch = PtrBatch::new();
        for i in 0..33u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i), i % 7);
        }
        let mut want_inc = Vec::new();
        SoftwareEngine.increment(&ctx, &batch, &mut want_inc).unwrap();
        assert_eq!(inc_got, want_inc);
    }

    #[test]
    fn version_and_magic_mismatches_error_loudly() {
        with_loopback(|s| {
            // wrong version
            let mut w = WireWriter::new();
            w.put_u32(MAGIC);
            w.put_u16(PROTOCOL_VERSION + 1);
            w.put_u8(Op::Ping as u8);
            let reply = roundtrip(s, w.bytes());
            let err = open_response(&reply).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("protocol"), "{msg}");
            // wrong magic: the worker answers an error frame rather
            // than dying, so the session survives for the next request
            let mut w = WireWriter::new();
            w.put_u32(0x1BADF00D);
            w.put_u16(PROTOCOL_VERSION);
            w.put_u8(Op::Ping as u8);
            let reply = roundtrip(s, w.bytes());
            assert!(open_response(&reply).is_err());
            // a well-formed ping still works on the same stream
            let reply = roundtrip(s, &encode_simple_request(Op::Ping));
            assert!(open_response(&reply).is_ok());
        });
    }

    #[test]
    fn shutdown_ends_the_session_with_an_ack() {
        let (mut client, mut server) = UnixStream::pair().expect("socketpair");
        let handle =
            std::thread::spawn(move || serve_session(&mut server));
        write_frame(&mut client, &encode_simple_request(Op::Shutdown)).unwrap();
        let reply = read_frame(&mut client).unwrap().expect("ack");
        assert!(open_response(&reply).is_ok());
        assert!(handle.join().unwrap().is_ok());
        // stream is now closed from the worker side
        assert!(matches!(read_frame(&mut client), Ok(None)));
    }

    #[test]
    fn oversized_frames_are_refused() {
        // hand-craft a header claiming u32::MAX body bytes
        let (mut tx, mut rx) = UnixStream::pair().expect("socketpair");
        tx.write_all(&u32::MAX.to_le_bytes()).expect("header write");
        let err = read_frame(&mut rx).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidData);
    }
}
