//! The vectorized software tier: lane-wise Algorithm 1 over
//! [`PtrBatch`] chunks.
//!
//! The paper's premise is that per-pointer address translation is pure
//! overhead; before hardware removes it, the host path can at least
//! stop paying a scalar divide and modulo per pointer.  This backend
//! processes [`SIMD_LANES`] pointers per iteration in
//! structure-of-arrays form:
//!
//! ```text
//!          lane 0     lane 1     lane 2     lane 3
//! phase  [ p0.phase | p1.phase | p2.phase | p3.phase ]  + incs
//! thinc  [  >>/mul  |  >>/mul  |  >>/mul  |  >>/mul  ]  blocksize
//! thread [  &/mul   |  &/mul   |  &/mul   |  &/mul   ]  numthreads
//! va     [  <</mul  |  <</mul  |  <</mul  |  <</mul  ]  elemsize
//! ```
//!
//! * **pow2 layouts** reduce to shift/mask lanes, hoisting the Figure-3
//!   log2 immediates already cached in [`EngineCtx`] — the same ops the
//!   hardware pipeline wires up, replicated across lanes.
//! * **general layouts** (CG's 112-byte rows, 56016-byte structs, any
//!   non-pow2 thread count) replace both div/mod pairs with the
//!   [`Recip`] multiply-by-reciprocal precomputed once per ctx — a
//!   Granlund–Montgomery strength reduction that is *exact* for every
//!   u64 numerator, so the lanes stay bit-identical to
//!   [`increment_general`](crate::sptr::increment_general).
//!
//! Portability: `std::simd` is nightly-only, so the lanes are
//! hand-unrolled over fixed `[u64; SIMD_LANES]` arrays — a shape LLVM
//! auto-vectorizes on every target that has vector units and compiles
//! to plain scalar code everywhere else, with no runtime CPU-feature
//! dispatch to get wrong.  Batch remainders (`n % SIMD_LANES`) run
//! through the same scalar [`SoftwareEngine::map_one`] the reference
//! backend uses, and the conformance suite
//! (`rust/tests/engine_conformance.rs`) checks the whole engine
//! differentially against [`SoftwareEngine`] on every NPB layout —
//! the runtime check that the vector math never drifts.
//!
//! The selector prices this tier from [`SimdEngine::calibrate`]
//! (`simd_ns_per_ptr`) behind a serial/vector cutover threshold, and
//! tallies [`SimdStats`] for every batch the tier serves.

use std::time::Instant;

use super::{
    AddressEngine, BatchOut, EngineCtx, EngineError, PtrBatch, SoftwareEngine,
};
use crate::sptr::{
    locality, ArrayLayout, BaseTable, Recip, SharedPtr, Topology,
};

/// Pointers processed per unrolled iteration (u64x4: one AVX2 register,
/// two NEON registers; still profitable as plain unrolled scalar code).
pub const SIMD_LANES: usize = 4;

/// Counters for the vectorized tier: batches served, pointers that went
/// through full lanes, and pointers handled by the scalar tail.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SimdStats {
    /// Batches served by the simd tier.
    pub batches: u64,
    /// Pointers processed in full `SIMD_LANES`-wide chunks.
    pub lane_ptrs: u64,
    /// Pointers processed by the scalar remainder loop.
    pub tail_ptrs: u64,
}

impl SimdStats {
    /// Fold another counter snapshot into this one (per-CPU merge).
    pub fn merge(&mut self, other: &SimdStats) {
        self.batches += other.batches;
        self.lane_ptrs += other.lane_ptrs;
        self.tail_ptrs += other.tail_ptrs;
    }
}

/// Per-batch hoisted geometry: every layout field the lane loops need,
/// the pow2 log2 immediates when the layout has them, and the
/// reciprocals [`EngineCtx`] precomputed for the general path.
#[derive(Clone, Copy)]
struct Geometry {
    bs: u64,
    es: u64,
    nt: u64,
    log2: Option<(u32, u32, u32)>,
    rbs: Recip,
    rnt: Recip,
}

impl Geometry {
    #[inline]
    fn of(ctx: &EngineCtx) -> Self {
        let layout = *ctx.layout();
        let (rbs, rnt) = ctx.recips();
        debug_assert_eq!(rbs.divisor(), layout.blocksize);
        debug_assert_eq!(rnt.divisor(), layout.numthreads as u64);
        Self {
            bs: layout.blocksize,
            es: layout.elemsize,
            nt: layout.numthreads as u64,
            log2: ctx.log2s(),
            rbs,
            rnt,
        }
    }
}

/// One unrolled chunk of Algorithm 1, general form: both div/mod pairs
/// strength-reduced to the precomputed reciprocals.  Each statement is
/// a `SIMD_LANES`-wide array expression so LLVM can keep the whole
/// chunk in vector registers.
#[inline(always)]
fn lanes_general(
    g: &Geometry,
    phase: &[u64; SIMD_LANES],
    thread: &[u64; SIMD_LANES],
    va: &[u64; SIMD_LANES],
    inc: &[u64; SIMD_LANES],
) -> [SharedPtr; SIMD_LANES] {
    let mut phinc = [0u64; SIMD_LANES];
    let mut thinc = [0u64; SIMD_LANES];
    let mut nphase = [0u64; SIMD_LANES];
    let mut tsum = [0u64; SIMD_LANES];
    let mut blockinc = [0u64; SIMD_LANES];
    let mut nthread = [0u64; SIMD_LANES];
    let mut nva = [0u64; SIMD_LANES];
    for l in 0..SIMD_LANES {
        phinc[l] = phase[l] + inc[l];
    }
    for l in 0..SIMD_LANES {
        thinc[l] = g.rbs.div(phinc[l]);
    }
    for l in 0..SIMD_LANES {
        // exact quotient above, so this multiply-subtract IS the mod
        nphase[l] = phinc[l] - thinc[l] * g.bs;
    }
    for l in 0..SIMD_LANES {
        tsum[l] = thread[l] + thinc[l];
    }
    for l in 0..SIMD_LANES {
        blockinc[l] = g.rnt.div(tsum[l]);
    }
    for l in 0..SIMD_LANES {
        nthread[l] = tsum[l] - blockinc[l] * g.nt;
    }
    for l in 0..SIMD_LANES {
        let eaddrinc =
            (nphase[l] as i64 - phase[l] as i64) + (blockinc[l] * g.bs) as i64;
        nva[l] = (va[l] as i64 + eaddrinc * g.es as i64) as u64;
    }
    std::array::from_fn(|l| SharedPtr {
        thread: nthread[l] as u32,
        phase: nphase[l],
        va: nva[l],
    })
}

/// One unrolled chunk of Algorithm 1, pow2 form: the hardware
/// pipeline's shift/mask datapath replicated across lanes, immediates
/// hoisted from the ctx cache.
#[inline(always)]
fn lanes_pow2(
    l2bs: u32,
    l2es: u32,
    l2nt: u32,
    phase: &[u64; SIMD_LANES],
    thread: &[u64; SIMD_LANES],
    va: &[u64; SIMD_LANES],
    inc: &[u64; SIMD_LANES],
) -> [SharedPtr; SIMD_LANES] {
    let bs_mask = (1u64 << l2bs) - 1;
    let nt_mask = (1u64 << l2nt) - 1;
    let mut phinc = [0u64; SIMD_LANES];
    let mut thinc = [0u64; SIMD_LANES];
    let mut nphase = [0u64; SIMD_LANES];
    let mut tsum = [0u64; SIMD_LANES];
    let mut blockinc = [0u64; SIMD_LANES];
    let mut nthread = [0u64; SIMD_LANES];
    let mut nva = [0u64; SIMD_LANES];
    for l in 0..SIMD_LANES {
        phinc[l] = phase[l] + inc[l];
    }
    for l in 0..SIMD_LANES {
        thinc[l] = phinc[l] >> l2bs;
    }
    for l in 0..SIMD_LANES {
        nphase[l] = phinc[l] & bs_mask;
    }
    for l in 0..SIMD_LANES {
        tsum[l] = thread[l] + thinc[l];
    }
    for l in 0..SIMD_LANES {
        blockinc[l] = tsum[l] >> l2nt;
    }
    for l in 0..SIMD_LANES {
        nthread[l] = tsum[l] & nt_mask;
    }
    for l in 0..SIMD_LANES {
        let eaddrinc =
            (nphase[l] as i64 - phase[l] as i64) + ((blockinc[l] << l2bs) as i64);
        nva[l] = (va[l] as i64 + (eaddrinc << l2es)) as u64;
    }
    std::array::from_fn(|l| SharedPtr {
        thread: nthread[l] as u32,
        phase: nphase[l],
        va: nva[l],
    })
}

/// Load one chunk into SoA lane arrays and run the geometry-matched
/// lane kernel.
#[inline(always)]
fn inc_chunk(
    g: &Geometry,
    ptrs: &[SharedPtr],
    incs: &[u64],
) -> [SharedPtr; SIMD_LANES] {
    debug_assert!(ptrs.len() == SIMD_LANES && incs.len() == SIMD_LANES);
    let mut phase = [0u64; SIMD_LANES];
    let mut thread = [0u64; SIMD_LANES];
    let mut va = [0u64; SIMD_LANES];
    let mut inc = [0u64; SIMD_LANES];
    for l in 0..SIMD_LANES {
        phase[l] = ptrs[l].phase;
        thread[l] = ptrs[l].thread as u64;
        va[l] = ptrs[l].va;
        inc[l] = incs[l];
    }
    match g.log2 {
        Some((l2bs, l2es, l2nt)) => {
            lanes_pow2(l2bs, l2es, l2nt, &phase, &thread, &va, &inc)
        }
        None => lanes_general(g, &phase, &thread, &va, &inc),
    }
}

/// The vectorized software backend.  Supports every layout; bit-
/// identical to [`SoftwareEngine`] on all of them (differentially
/// enforced by the conformance suite).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimdEngine;

impl SimdEngine {
    /// Measure this host's vectorized per-pointer translate cost in
    /// nanoseconds (`simd_ns_per_ptr` for the
    /// [`CostModel`](super::CostModel)).  Uses a non-pow2 CG-style
    /// layout so the measurement covers the reciprocal path — the
    /// expensive one; pow2 lanes only run faster.
    pub fn calibrate() -> f64 {
        const N: usize = 4096;
        const ROUNDS: u32 = 8;
        let layout = ArrayLayout::new(3, 112, 5);
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0)
            .expect("calibration ctx is statically valid");
        let mut batch = PtrBatch::with_capacity(N);
        let mut x = 0x9E37_79B9_7F4A_7C15u64;
        for _ in 0..N {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            batch.push(
                SharedPtr::for_index(&layout, 0, x >> 48),
                (x >> 32) & 0xFFF,
            );
        }
        let mut out = BatchOut::new();
        SimdEngine.translate(&ctx, &batch, &mut out).expect("calibration run");
        let t0 = Instant::now();
        for _ in 0..ROUNDS {
            SimdEngine.translate(&ctx, &batch, &mut out).expect("calibration run");
        }
        let ns = t0.elapsed().as_nanos() as f64 / (ROUNDS as usize * N) as f64;
        ns.max(0.01)
    }
}

impl AddressEngine for SimdEngine {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn supports(&self, _layout: &ArrayLayout) -> bool {
        true
    }

    fn translate(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        batch.check()?;
        out.clear();
        let n = batch.len();
        out.reserve(n);
        let g = Geometry::of(ctx);
        let layout = *ctx.layout();
        let table = ctx.table();
        let mythread = ctx.mythread();
        let topo = *ctx.topo();
        let lanes = n - n % SIMD_LANES;
        let mut i = 0;
        while i < lanes {
            let q = inc_chunk(
                &g,
                &batch.ptrs[i..i + SIMD_LANES],
                &batch.incs[i..i + SIMD_LANES],
            );
            // epilogue per lane: LUT gather + locality classification
            // (inherently scalar — a table lookup per distinct thread)
            for p in q {
                out.push(
                    p,
                    p.translate(table),
                    locality(p.thread, mythread, &topo),
                );
            }
            i += SIMD_LANES;
        }
        for k in lanes..n {
            // scalar tail: the reference path itself, so the remainder
            // cannot drift from SoftwareEngine
            let (p, sysva, loc) = SoftwareEngine::map_one(
                &layout,
                table,
                mythread,
                &topo,
                &batch.ptrs[k],
                batch.incs[k],
            );
            out.push(p, sysva, loc);
        }
        Ok(())
    }

    fn increment(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        batch.check()?;
        out.clear();
        let n = batch.len();
        out.reserve(n);
        let g = Geometry::of(ctx);
        let layout = *ctx.layout();
        let lanes = n - n % SIMD_LANES;
        let mut i = 0;
        while i < lanes {
            let q = inc_chunk(
                &g,
                &batch.ptrs[i..i + SIMD_LANES],
                &batch.incs[i..i + SIMD_LANES],
            );
            out.extend_from_slice(&q);
            i += SIMD_LANES;
        }
        for k in lanes..n {
            out.push(crate::sptr::increment_general(
                &batch.ptrs[k],
                batch.incs[k],
                &layout,
            ));
        }
        Ok(())
    }

    /// Walks already run O(1) per step through the stepper cursor;
    /// there is nothing lane-parallel to exploit, so this tier serves
    /// them exactly like the scalar backends.
    fn walk(
        &self,
        ctx: &EngineCtx,
        start: SharedPtr,
        inc: u64,
        steps: usize,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        super::cursor_walk(ctx, start, inc, steps, out)
    }

    fn translate_one(
        &self,
        ctx: &EngineCtx,
        ptr: SharedPtr,
        inc: u64,
    ) -> Result<(SharedPtr, u64, crate::sptr::Locality), EngineError> {
        // single pointers take the reference scalar path directly
        Ok(SoftwareEngine::map_one(
            ctx.layout(),
            ctx.table(),
            ctx.mythread(),
            ctx.topo(),
            &ptr,
            inc,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;
    use crate::util::testkit::check;

    fn random_case(
        rng: &mut Xoshiro256,
        pow2: bool,
    ) -> (ArrayLayout, BaseTable, u32, PtrBatch) {
        let layout = if pow2 {
            ArrayLayout::new(
                1 << rng.below(9),
                1 << rng.below(6),
                1 << rng.below(6) as u32,
            )
        } else {
            let elemsize = [1, 2, 4, 8, 24, 112, 56016][rng.below(7) as usize];
            ArrayLayout::new(
                rng.below(64) + 1,
                elemsize,
                rng.below(63) as u32 + 1,
            )
        };
        let table = BaseTable::regular(layout.numthreads, 1 << 32, 1 << 32);
        let mythread = rng.below(layout.numthreads as u64) as u32;
        // sizes straddle the lane width so tails of 0..=3 all occur
        let n = 1 + rng.below(257) as usize;
        let mut batch = PtrBatch::with_capacity(n);
        for _ in 0..n {
            batch.push(
                SharedPtr::for_index(&layout, 0, rng.below(1 << 16)),
                rng.below(1 << 13),
            );
        }
        (layout, table, mythread, batch)
    }

    #[test]
    fn simd_matches_software_on_random_layouts() {
        check("simd == software (translate/increment)", 96, |rng| {
            let pow2 = rng.below(2) == 0;
            let (layout, table, mythread, batch) = random_case(rng, pow2);
            let ctx = EngineCtx::new(layout, &table, mythread)
                .unwrap()
                .with_topology(Topology {
                    log2_threads_per_mc: 1,
                    log2_threads_per_node: 3,
                });
            let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
            SimdEngine.translate(&ctx, &batch, &mut a).unwrap();
            SoftwareEngine.translate(&ctx, &batch, &mut b).unwrap();
            assert_eq!(a, b, "translate layout={layout:?} n={}", batch.len());
            let (mut pa, mut pb) = (Vec::new(), Vec::new());
            SimdEngine.increment(&ctx, &batch, &mut pa).unwrap();
            SoftwareEngine.increment(&ctx, &batch, &mut pb).unwrap();
            assert_eq!(pa, pb, "increment layout={layout:?}");
        });
    }

    #[test]
    fn scalar_tail_sizes_are_all_exercised() {
        // n = 1..=9 covers every n % SIMD_LANES remainder twice
        let layout = ArrayLayout::new(3, 112, 5);
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 2).unwrap();
        for n in 1..=9usize {
            let mut batch = PtrBatch::with_capacity(n);
            for i in 0..n {
                batch.push(
                    SharedPtr::for_index(&layout, 0, i as u64 * 7),
                    i as u64 + 1,
                );
            }
            let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
            SimdEngine.translate(&ctx, &batch, &mut a).unwrap();
            SoftwareEngine.translate(&ctx, &batch, &mut b).unwrap();
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn translate_one_matches_reference() {
        let layout = ArrayLayout::new(5, 24, 6);
        let table = BaseTable::regular(6, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 1).unwrap();
        let p = SharedPtr::for_index(&layout, 0, 11);
        assert_eq!(
            SimdEngine.translate_one(&ctx, p, 9).unwrap(),
            SoftwareEngine.translate_one(&ctx, p, 9).unwrap()
        );
    }

    #[test]
    fn calibrate_returns_a_positive_cost() {
        assert!(SimdEngine::calibrate() > 0.0);
    }
}
