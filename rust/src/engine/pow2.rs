//! The hardware fast-path backend: Algorithm 1 in shift/mask form — the
//! datapath the paper's increment unit pipelines over two stages.  Only
//! legal when blocksize, elemsize and numthreads are all powers of two
//! (paper 4.2); any other layout is refused, mirroring the compiler's
//! software fallback for the `Hw` lowering.

use super::{AddressEngine, BatchOut, EngineCtx, EngineError, PtrBatch};
use crate::sptr::{increment_pow2, locality, ArrayLayout, Locality, SharedPtr};

/// Shift/mask Algorithm 1.  Refuses non-pow2 layouts.
#[derive(Clone, Copy, Debug, Default)]
pub struct Pow2Engine;

impl Pow2Engine {
    /// The Figure-3 log2 immediates — precomputed once per
    /// [`EngineCtx`] at construction, so the per-call paths only read
    /// the cache (or refuse with `UnsupportedLayout`).
    fn log2s(ctx: &EngineCtx) -> Result<(u32, u32, u32), EngineError> {
        ctx.log2s().ok_or(EngineError::UnsupportedLayout {
            engine: "pow2",
            layout: ctx.layout,
        })
    }
}

impl AddressEngine for Pow2Engine {
    fn name(&self) -> &'static str {
        "pow2"
    }

    fn supports(&self, layout: &ArrayLayout) -> bool {
        layout.hw_supported()
    }

    fn translate(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        let (l2bs, l2es, l2nt) = Self::log2s(ctx)?;
        batch.check()?;
        out.clear();
        out.reserve(batch.len());
        for (p, &inc) in batch.ptrs.iter().zip(&batch.incs) {
            let q = increment_pow2(p, inc, l2bs, l2es, l2nt);
            let sysva = q.translate(ctx.table);
            out.push(q, sysva, locality(q.thread, ctx.mythread, &ctx.topo));
        }
        Ok(())
    }

    fn increment(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        let (l2bs, l2es, l2nt) = Self::log2s(ctx)?;
        batch.check()?;
        out.clear();
        out.reserve(batch.len());
        for (p, &inc) in batch.ptrs.iter().zip(&batch.incs) {
            out.push(increment_pow2(p, inc, l2bs, l2es, l2nt));
        }
        Ok(())
    }

    /// Walks are O(1) per step via [`crate::sptr::WalkCursor`]; the
    /// log2 gate only decides whether this backend may serve the
    /// layout at all.
    fn walk(
        &self,
        ctx: &EngineCtx,
        start: SharedPtr,
        inc: u64,
        steps: usize,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        Self::log2s(ctx)?;
        super::cursor_walk(ctx, start, inc, steps, out)
    }

    fn translate_one(
        &self,
        ctx: &EngineCtx,
        ptr: SharedPtr,
        inc: u64,
    ) -> Result<(SharedPtr, u64, Locality), EngineError> {
        let (l2bs, l2es, l2nt) = Self::log2s(ctx)?;
        let q = increment_pow2(&ptr, inc, l2bs, l2es, l2nt);
        let sysva = q.translate(ctx.table);
        Ok((q, sysva, locality(q.thread, ctx.mythread, &ctx.topo)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sptr::BaseTable;

    #[test]
    fn refuses_nonpow2_layouts() {
        let layout = ArrayLayout::new(3, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let e = Pow2Engine;
        assert!(!e.supports(&layout));
        let mut out = BatchOut::new();
        let err = e.walk(&ctx, SharedPtr::NULL, 1, 4, &mut out).unwrap_err();
        assert!(matches!(err, EngineError::UnsupportedLayout { engine: "pow2", .. }));
    }

    #[test]
    fn agrees_with_software_on_pow2_layout() {
        use super::super::SoftwareEngine;
        let layout = ArrayLayout::new(8, 4, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 1).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 3), i);
        }
        let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
        Pow2Engine.translate(&ctx, &batch, &mut a).unwrap();
        SoftwareEngine.translate(&ctx, &batch, &mut b).unwrap();
        assert_eq!(a, b);
    }
}
