//! Cost-based backend selection — the runtime mirror of the
//! compiler's `Soft`/`Hw` lowering choice, extended from a fixed
//! priority list to a priced argmin over the available backends.
//!
//! For every `(layout, batch_len)` request the selector prices each
//! legal backend with a [`CostModel`] and serves the cheapest:
//!
//! * scalar paths cost `n · ns_per_ptr` (shift/mask `pow2` when the
//!   geometry allows it, software Algorithm 1 otherwise);
//! * the sharded worker pool costs a fixed scatter/gather fee plus the
//!   scalar per-pointer cost divided by the worker count, and is only
//!   eligible once `batch_len` reaches `shard_threshold`;
//! * the XLA batch unit (built with `--features xla-unit` and loaded)
//!   costs a PJRT dispatch fee plus a small per-pointer cost, eligible
//!   from `xla_threshold`;
//! * the Leon3 coprocessor model (installed with
//!   [`EngineSelector::with_leon3`]) costs a per-batch core-setup fee
//!   plus a per-pointer instruction-replay cost **measured at install
//!   time** ([`Leon3Engine::calibrate`]) — honest pricing keeps the
//!   functional-core replay out of the hot path while still letting a
//!   recalibrated model (e.g. one mirroring real silicon) win;
//! * the remote worker-process pool (installed with
//!   [`EngineSelector::with_remote`]) costs a scatter/gather fee plus a
//!   marginal per-pointer cost, both **measured at install time** by a
//!   `RemoteEngine::calibrate` round-trip and gated by
//!   `remote_threshold` — the socket hop only wins where the measured
//!   model says it does;
//! * the vectorized software tier ([`SimdEngine`]) costs a per-pointer
//!   lane price (`simd_ns_per_ptr`, measured by
//!   [`EngineSelector::with_simd_calibration`]) past a
//!   `PAR_THRESHOLD`-style serial/vector cutover (`simd_threshold`);
//!   batches past `plan_threshold` are additionally tiled by the
//!   cache-blocked, affinity-sorted [`TilePlan`] planner before
//!   dispatch;
//! * walks are priced separately off the O(1)
//!   [`WalkCursor`](crate::sptr::WalkCursor) stepper cost — a walk's
//!   scalar path is cheap regardless of layout, so walks shard only at
//!   much larger step counts than translates.
//!
//! Install-time calibrations are stored beside the model and re-applied
//! whenever [`EngineSelector::with_cost_model`] replaces the constants,
//! so builder order cannot silently discard a measurement.
//!
//! The pool's parallelism is capped by what a batch can actually keep
//! busy (`n / min_shard_len` shards), and per-choice hit counters
//! record which backend actually served each passthrough request;
//! `coordinator::engine_report` archives that mix alongside every
//! sweep.
//!
//! ## Health tracking & graceful degradation
//!
//! Every passthrough dispatch feeds a per-backend health record:
//! consecutive-failure and EWMA error counters drive a circuit breaker
//! (closed → open → half-open probe, [`BreakerState`]).  A tripped
//! tier is *quarantined* — the argmin simply re-runs over the
//! surviving backends — until a cooldown elapses and one half-open
//! probe dispatch decides whether it closes again.  Each dispatch also
//! carries a deadline priced off the [`CostModel`] estimate; an
//! over-deadline or failed ([`EngineError::Backend`]) call is
//! transparently re-served by the always-legal fallback ladder
//! (sharded pool where the batch warrants it, else the pow2/software
//! scalar floor), so transient faults never change results and never
//! reach the caller.  Structural refusals (`UnsupportedLayout`,
//! `TableTooSmall`, `LengthMismatch`) are deterministic caller errors
//! and still propagate loudly.  [`HealthStats`] snapshots the whole
//! ladder for `stats_txt` / `coordinator::health_table`; a seeded
//! [`FaultPlan`] installed with
//! [`with_chaos`](EngineSelector::with_chaos) injects reproducible
//! faults at this funnel (the `--chaos` CLI flag).

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use super::fault::{EngineFault, FaultPlan};
use super::gather::{GatherPlan, GatherStats};
use super::plan::{PlanStats, TilePlan, L2_TILE_PTRS};
use super::remote::RemoteEngine;
use super::simd::{SimdEngine, SimdStats, SIMD_LANES};
use super::{
    AddressEngine, BatchOut, EngineCtx, EngineError, Leon3Engine, Pow2Engine,
    PtrBatch, ShardedEngine, SoftwareEngine,
};
use crate::sptr::{ArrayLayout, Locality, SharedPtr};

/// Which backend the selector picked (stable, reportable).  The
/// declaration order is the hit-counter index (`ALL` and the
/// discriminant derive from it).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EngineChoice {
    /// General Algorithm 1 (divide/modulo), legal for every layout.
    Software,
    /// Shift/mask fast path, pow2 layouts only.
    Pow2,
    /// The worker-pool tier wrapping the scalar policy.
    Sharded,
    /// The PJRT/XLA batch unit (`xla-unit` feature, artifacts loaded).
    XlaBatch,
    /// The Leon3 FPGA-coprocessor model (instruction replay).
    Leon3,
    /// The worker-process pool behind Unix-domain sockets
    /// ([`RemoteEngine`] — address mapping as a service).
    Remote,
    /// The vectorized software tier ([`SimdEngine`]): lane-wise
    /// shift/mask on pow2 layouts, multiply-by-reciprocal otherwise.
    Simd,
}

impl EngineChoice {
    /// Number of reportable backends — the length of [`ALL`](Self::ALL)
    /// and of every hit-counter / [`EngineMix`](crate::cpu::EngineMix)
    /// array indexed by [`index`](Self::index).
    pub const COUNT: usize = 7;

    /// Every backend the selector can report, in hit-counter order.
    pub const ALL: [EngineChoice; Self::COUNT] = [
        EngineChoice::Software,
        EngineChoice::Pow2,
        EngineChoice::Sharded,
        EngineChoice::XlaBatch,
        EngineChoice::Leon3,
        EngineChoice::Remote,
        EngineChoice::Simd,
    ];

    /// Stable name used in reports and selection tables.
    pub fn name(&self) -> &'static str {
        match self {
            EngineChoice::Software => "software",
            EngineChoice::Pow2 => "pow2",
            EngineChoice::Sharded => "sharded",
            EngineChoice::XlaBatch => "xla-batch",
            EngineChoice::Leon3 => "leon3",
            EngineChoice::Remote => "remote",
            EngineChoice::Simd => "simd",
        }
    }

    /// Hit-counter / [`EngineMix`](crate::cpu::EngineMix) slot of this
    /// choice (its position in [`ALL`](Self::ALL)).
    pub fn index(&self) -> usize {
        *self as usize
    }
}

/// The selector's scalar policy packaged as an engine: the pow2
/// shift/mask path whenever the layout allows it (read off the
/// [`EngineCtx`]'s cached log2 immediates), software Algorithm 1
/// otherwise.  Serves as the inner engine of the selector's sharded
/// pool so every worker applies the same per-layout choice.
#[derive(Clone, Copy, Debug, Default)]
pub struct AutoEngine;

impl AutoEngine {
    /// The one pow2-else-software dispatch, shared by every method.
    fn pick(ctx: &EngineCtx) -> &'static dyn AddressEngine {
        if ctx.log2s().is_some() {
            &Pow2Engine
        } else {
            &SoftwareEngine
        }
    }
}

impl AddressEngine for AutoEngine {
    fn name(&self) -> &'static str {
        "auto"
    }

    fn supports(&self, _layout: &ArrayLayout) -> bool {
        true
    }

    fn translate(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        Self::pick(ctx).translate(ctx, batch, out)
    }

    fn increment(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        Self::pick(ctx).increment(ctx, batch, out)
    }

    fn walk(
        &self,
        ctx: &EngineCtx,
        start: SharedPtr,
        inc: u64,
        steps: usize,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        Self::pick(ctx).walk(ctx, start, inc, steps, out)
    }

    fn translate_one(
        &self,
        ctx: &EngineCtx,
        ptr: SharedPtr,
        inc: u64,
    ) -> Result<(SharedPtr, u64, Locality), EngineError> {
        Self::pick(ctx).translate_one(ctx, ptr, inc)
    }
}

/// Tunable per-pointer / per-dispatch cost constants, in nanoseconds.
///
/// The absolute values only need to be right relative to each other —
/// the selector takes an argmin, so what matters is where the curves
/// cross: a fixed dispatch fee (channel scatter/gather, PJRT
/// round-trip) amortized against a per-pointer saving.  Defaults come
/// from the `hotpath_engine` micro-bench on a commodity host.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// ns per pointer on the software divide/modulo path
    /// (≈ [`SOFT_INC_OP_COUNT`](crate::sptr::SOFT_INC_OP_COUNT) ops).
    pub software_ns_per_ptr: f64,
    /// ns per pointer on the pow2 shift/mask path.
    pub pow2_ns_per_ptr: f64,
    /// ns per step of a constant-stride walk — the
    /// [`WalkCursor`](crate::sptr::WalkCursor) stepper, whose cost is
    /// layout-independent (add-and-carry, no div/mod).
    pub walk_ns_per_step: f64,
    /// Fixed fee to scatter a batch over the shard pool and splice the
    /// results (channel round-trips).
    pub shard_dispatch_ns: f64,
    /// Per-pointer sharding overhead that does **not** parallelize:
    /// copying shard inputs out and splicing outputs back.
    pub shard_copy_ns_per_ptr: f64,
    /// ns per pointer inside the XLA batch unit.
    pub xla_ns_per_ptr: f64,
    /// Fixed PJRT dispatch fee.
    pub xla_dispatch_ns: f64,
    /// ns per pointer replayed through the Leon3 functional core.
    /// [`EngineSelector::with_leon3`] overwrites the default with the
    /// value [`Leon3Engine::calibrate`] measures on this host; the
    /// default is the order of magnitude the `hotpath_engine` bench
    /// records (instruction-by-instruction replay, not arithmetic).
    pub leon3_ns_per_ptr: f64,
    /// Fixed per-batch fee for the Leon3 backend: constructing the
    /// functional core state (registers + base LUT) for the request.
    /// Also measured (not guessed) by [`EngineSelector::with_leon3`].
    pub leon3_dispatch_ns: f64,
    /// Marginal ns per pointer through the remote worker-process pool
    /// (serialization + socket + divided-down compute).  Measured by
    /// `RemoteEngine::calibrate` when the tier is installed via
    /// [`EngineSelector::with_remote`]; the default is the order of
    /// magnitude Unix-domain sockets cost on a commodity host.
    pub remote_ns_per_ptr: f64,
    /// Fixed scatter/gather fee for one remote request (frame
    /// round-trips across every shard).  Also measured, not guessed.
    pub remote_dispatch_ns: f64,
    /// ns per pointer the inspector pays to bucket an irregular batch
    /// by owning thread (one div + one mod + one map probe).  Measured
    /// by [`EngineSelector::with_gather_calibration`] via
    /// [`GatherPlan::calibrate`]; the default is the `hotpath_engine`
    /// order of magnitude.
    pub gather_bucket_ns_per_ptr: f64,
    /// ns per pointer on the vectorized software path (lane-wise
    /// shift/mask or multiply-by-reciprocal).  Measured on the non-pow2
    /// reciprocal path by [`EngineSelector::with_simd_calibration`] via
    /// [`SimdEngine::calibrate`]; the default sits between the pow2 and
    /// software scalar legs, so the argmin keeps the shift/mask scalar
    /// path on pow2 geometry and routes big non-pow2 batches here.
    pub simd_ns_per_ptr: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            software_ns_per_ptr: 12.0,
            pow2_ns_per_ptr: 3.0,
            walk_ns_per_step: 3.0,
            shard_dispatch_ns: 40_000.0,
            shard_copy_ns_per_ptr: 1.5,
            xla_ns_per_ptr: 0.8,
            xla_dispatch_ns: 60_000.0,
            leon3_ns_per_ptr: 150.0,
            leon3_dispatch_ns: 5_000.0,
            remote_ns_per_ptr: 25.0,
            remote_dispatch_ns: 150_000.0,
            gather_bucket_ns_per_ptr: 2.0,
            simd_ns_per_ptr: 4.0,
        }
    }
}

impl CostModel {
    fn scalar_ns_per_ptr(&self, layout: &ArrayLayout) -> f64 {
        if layout.hw_supported() {
            self.pow2_ns_per_ptr
        } else {
            self.software_ns_per_ptr
        }
    }

    /// Core shape shared by batch and walk estimates: scalar work per
    /// item vs a fixed fee plus divided-down work plus splice copies.
    fn estimate_with(
        &self,
        choice: EngineChoice,
        scalar_ns: f64,
        n: usize,
        shard_workers: usize,
    ) -> f64 {
        let n = n as f64;
        match choice {
            EngineChoice::Software | EngineChoice::Pow2 => n * scalar_ns,
            EngineChoice::Sharded => {
                self.shard_dispatch_ns
                    + n * (scalar_ns / shard_workers.max(1) as f64
                        + self.shard_copy_ns_per_ptr)
            }
            EngineChoice::XlaBatch => {
                self.xla_dispatch_ns + n * self.xla_ns_per_ptr
            }
            EngineChoice::Leon3 => {
                self.leon3_dispatch_ns + n * self.leon3_ns_per_ptr
            }
            EngineChoice::Remote => {
                self.remote_dispatch_ns + n * self.remote_ns_per_ptr
            }
            EngineChoice::Simd => n * self.simd_ns_per_ptr,
        }
    }

    /// Estimated cost (ns) of serving `n` batched requests of `layout`
    /// with `choice`, given `shard_workers` effective pool workers.
    pub fn estimate(
        &self,
        choice: EngineChoice,
        layout: &ArrayLayout,
        n: usize,
        shard_workers: usize,
    ) -> f64 {
        self.estimate_with(choice, self.scalar_ns_per_ptr(layout), n, shard_workers)
    }

    /// Estimated cost (ns) of an `n`-step constant-stride walk — priced
    /// off the O(1) stepper, not the batch translate path, so mid-size
    /// walks are not misrouted to the pool.
    pub fn estimate_walk(
        &self,
        choice: EngineChoice,
        n: usize,
        shard_workers: usize,
    ) -> f64 {
        self.estimate_with(choice, self.walk_ns_per_step, n, shard_workers)
    }
}

/// Calibration measurements taken when a backend was installed, kept
/// separately from the live [`CostModel`] so a later
/// [`with_cost_model`](EngineSelector::with_cost_model) can re-apply
/// them — builder order no longer matters.
#[derive(Clone, Copy, Debug, Default)]
struct MeasuredLegs {
    /// `(ns_per_ptr, dispatch_ns)` from `Leon3Engine::calibrate`.
    leon3: Option<(f64, f64)>,
    /// `(ns_per_ptr, dispatch_ns)` from `RemoteEngine::calibrate` (or
    /// the forced-tier pricing explicitly installed with it).
    remote: Option<(f64, f64)>,
    /// `ns_per_ptr` from [`SimdEngine::calibrate`] (or a forced value
    /// installed with [`EngineSelector::with_simd_cost`]).
    simd: Option<f64>,
}

/// Interior-mutable counters behind the selector's gather leg
/// (snapshotted as [`GatherStats`]).
#[derive(Debug, Default)]
struct GatherCounters {
    plans: AtomicU64,
    bucketed_ptrs: AtomicU64,
    fallback: AtomicU64,
}

impl GatherCounters {
    fn snapshot(&self) -> GatherStats {
        GatherStats {
            plans: self.plans.load(Ordering::Relaxed),
            bucketed_ptrs: self.bucketed_ptrs.load(Ordering::Relaxed),
            fallback: self.fallback.load(Ordering::Relaxed),
        }
    }
}

/// Interior-mutable counters behind the vectorized tier (snapshotted as
/// [`SimdStats`]).
#[derive(Debug, Default)]
struct SimdCounters {
    batches: AtomicU64,
    lane_ptrs: AtomicU64,
    tail_ptrs: AtomicU64,
}

impl SimdCounters {
    fn snapshot(&self) -> SimdStats {
        SimdStats {
            batches: self.batches.load(Ordering::Relaxed),
            lane_ptrs: self.lane_ptrs.load(Ordering::Relaxed),
            tail_ptrs: self.tail_ptrs.load(Ordering::Relaxed),
        }
    }
}

/// Interior-mutable counters behind the cache-blocked batch planner
/// (snapshotted as [`PlanStats`]).
#[derive(Debug, Default)]
struct PlanCounters {
    plans: AtomicU64,
    tiles: AtomicU64,
    planned_ptrs: AtomicU64,
    fallback: AtomicU64,
}

impl PlanCounters {
    fn snapshot(&self) -> PlanStats {
        PlanStats {
            plans: self.plans.load(Ordering::Relaxed),
            tiles: self.tiles.load(Ordering::Relaxed),
            planned_ptrs: self.planned_ptrs.load(Ordering::Relaxed),
            fallback: self.fallback.load(Ordering::Relaxed),
        }
    }
}

/// Circuit-breaker state of one backend tier.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: the tier competes in the argmin.
    #[default]
    Closed,
    /// Quarantined: repeated failures; skipped by the argmin until the
    /// cooldown elapses.
    Open,
    /// One probe dispatch is in flight; its outcome decides whether the
    /// tier closes again or re-opens.
    HalfOpen,
}

impl BreakerState {
    fn from_u8(v: u8) -> Self {
        match v {
            1 => BreakerState::Open,
            2 => BreakerState::HalfOpen,
            _ => BreakerState::Closed,
        }
    }

    /// Severity rank for merging per-core snapshots (worst wins).
    fn rank(self) -> u8 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::HalfOpen => 1,
            BreakerState::Open => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// Health snapshot of one backend tier (one row of
/// `coordinator::health_table`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierHealthStats {
    /// Dispatches this tier served cleanly (within deadline).
    pub successes: u64,
    /// Dispatches this tier failed (backend error, injected fault, or
    /// past deadline).
    pub failures: u64,
    /// Closed → open breaker transitions.
    pub trips: u64,
    /// Half-open probe dispatches granted after a cooldown.
    pub probes: u64,
    /// Breaker state at snapshot time.
    pub state: BreakerState,
}

/// Snapshot of the selector's whole degradation ladder, merged across
/// cores into [`MachineResult`](crate::sim::MachineResult) and printed
/// as the `health.*` / `degrade.*` lines of `stats_txt`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HealthStats {
    /// Total dispatches through the selector funnel.
    pub dispatches: u64,
    /// Dispatches transparently re-served by the fallback ladder.
    pub fallback_runs: u64,
    /// Dispatches that ran past their cost-model deadline.
    pub deadline_misses: u64,
    /// Faults injected by an installed chaos plan.
    pub injected_faults: u64,
    /// Per-tier counters, indexed by [`EngineChoice::index`].
    pub tiers: [TierHealthStats; EngineChoice::COUNT],
}

impl HealthStats {
    /// Accumulate another snapshot (per-core merge).
    pub fn merge(&mut self, o: &HealthStats) {
        self.dispatches += o.dispatches;
        self.fallback_runs += o.fallback_runs;
        self.deadline_misses += o.deadline_misses;
        self.injected_faults += o.injected_faults;
        for (t, ot) in self.tiers.iter_mut().zip(o.tiers.iter()) {
            t.successes += ot.successes;
            t.failures += ot.failures;
            t.trips += ot.trips;
            t.probes += ot.probes;
            if ot.state.rank() > t.state.rank() {
                t.state = ot.state;
            }
        }
    }

    /// Total failures across tiers.
    pub fn failures(&self) -> u64 {
        self.tiers.iter().map(|t| t.failures).sum()
    }

    /// Total breaker trips across tiers.
    pub fn trips(&self) -> u64 {
        self.tiers.iter().map(|t| t.trips).sum()
    }

    /// Total half-open probes across tiers.
    pub fn probes(&self) -> u64 {
        self.tiers.iter().map(|t| t.probes).sum()
    }

    /// Tiers currently not closed (open or probing).
    pub fn quarantined(&self) -> usize {
        self.tiers
            .iter()
            .filter(|t| t.state != BreakerState::Closed)
            .count()
    }
}

/// Per-tier health record: lock-free counters plus the breaker word
/// (the selector is shared `&self` across passthroughs, so everything
/// here is atomic like the hit counters).
#[derive(Default)]
struct TierHealth {
    /// Breaker word (`BreakerState` encoding).
    state: AtomicU8,
    /// Consecutive failures since the last success.
    consec: AtomicU32,
    /// Failure-rate EWMA, scaled by 1000 (0 = never fails).
    ewma_milli: AtomicU32,
    /// Global dispatch-clock value when the breaker last opened.
    opened_at: AtomicU64,
    successes: AtomicU64,
    failures: AtomicU64,
    trips: AtomicU64,
    probes: AtomicU64,
}

/// The selector-wide ladder state behind [`HealthStats`].
#[derive(Default)]
struct Health {
    tiers: [TierHealth; EngineChoice::COUNT],
    /// Monotonic dispatch counter — the breaker's cooldown clock.
    dispatches: AtomicU64,
    fallback_runs: AtomicU64,
    deadline_misses: AtomicU64,
    injected_faults: AtomicU64,
}

impl Health {
    /// Consecutive failures that trip a closed breaker.
    const TRIP_CONSEC: u32 = 3;
    /// EWMA failure rate (milli-units) that trips a closed breaker.
    const TRIP_EWMA_MILLI: u32 = 500;
    /// Dispatches an open breaker waits before granting one probe.
    const COOLDOWN_DISPATCHES: u64 = 64;

    /// One success: reset the failure streak, decay the EWMA, and close
    /// a half-open breaker (the probe succeeded).
    fn on_success(&self, tier: EngineChoice) {
        let t = &self.tiers[tier.index()];
        t.successes.fetch_add(1, Ordering::Relaxed);
        t.consec.store(0, Ordering::Relaxed);
        let e = t.ewma_milli.load(Ordering::Relaxed);
        t.ewma_milli.store(e - e / 8, Ordering::Relaxed);
        let _ = t.state.compare_exchange(
            BreakerState::HalfOpen as u8,
            BreakerState::Closed as u8,
            Ordering::Relaxed,
            Ordering::Relaxed,
        );
    }

    /// One failure: bump the streak and EWMA; trip a closed breaker
    /// past either threshold, re-open a half-open one (probe failed).
    fn on_failure(&self, tier: EngineChoice, clock: u64) {
        let t = &self.tiers[tier.index()];
        t.failures.fetch_add(1, Ordering::Relaxed);
        let consec = t.consec.fetch_add(1, Ordering::Relaxed) + 1;
        let e = t.ewma_milli.load(Ordering::Relaxed);
        let e = e - e / 8 + 125; // decay 1/8, add 1000/8
        t.ewma_milli.store(e, Ordering::Relaxed);
        let state = BreakerState::from_u8(t.state.load(Ordering::Relaxed));
        let trip = match state {
            BreakerState::Closed => {
                consec >= Self::TRIP_CONSEC || e >= Self::TRIP_EWMA_MILLI
            }
            BreakerState::HalfOpen => true,
            BreakerState::Open => false,
        };
        if trip {
            t.opened_at.store(clock, Ordering::Relaxed);
            t.state.store(BreakerState::Open as u8, Ordering::Relaxed);
            if state == BreakerState::Closed {
                t.trips.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// May the argmin price this tier right now?  Closed admits; open
    /// admits exactly one probe dispatch per elapsed cooldown (the
    /// winner of the open → half-open CAS); half-open excludes everyone
    /// but the in-flight probe.
    fn admit(&self, tier: EngineChoice) -> bool {
        let t = &self.tiers[tier.index()];
        match BreakerState::from_u8(t.state.load(Ordering::Relaxed)) {
            BreakerState::Closed => true,
            BreakerState::HalfOpen => false,
            BreakerState::Open => {
                let clock = self.dispatches.load(Ordering::Relaxed);
                let opened = t.opened_at.load(Ordering::Relaxed);
                if clock.saturating_sub(opened) < Self::COOLDOWN_DISPATCHES {
                    return false;
                }
                let won = t
                    .state
                    .compare_exchange(
                        BreakerState::Open as u8,
                        BreakerState::HalfOpen as u8,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    )
                    .is_ok();
                if won {
                    t.probes.fetch_add(1, Ordering::Relaxed);
                }
                won
            }
        }
    }

    fn snapshot(&self) -> HealthStats {
        let mut s = HealthStats {
            dispatches: self.dispatches.load(Ordering::Relaxed),
            fallback_runs: self.fallback_runs.load(Ordering::Relaxed),
            deadline_misses: self.deadline_misses.load(Ordering::Relaxed),
            injected_faults: self.injected_faults.load(Ordering::Relaxed),
            tiers: Default::default(),
        };
        for (i, t) in self.tiers.iter().enumerate() {
            s.tiers[i] = TierHealthStats {
                successes: t.successes.load(Ordering::Relaxed),
                failures: t.failures.load(Ordering::Relaxed),
                trips: t.trips.load(Ordering::Relaxed),
                probes: t.probes.load(Ordering::Relaxed),
                state: BreakerState::from_u8(t.state.load(Ordering::Relaxed)),
            };
        }
        s
    }

    fn reset(&self) {
        self.dispatches.store(0, Ordering::Relaxed);
        self.fallback_runs.store(0, Ordering::Relaxed);
        self.deadline_misses.store(0, Ordering::Relaxed);
        self.injected_faults.store(0, Ordering::Relaxed);
        for t in &self.tiers {
            t.state.store(BreakerState::Closed as u8, Ordering::Relaxed);
            t.consec.store(0, Ordering::Relaxed);
            t.ewma_milli.store(0, Ordering::Relaxed);
            t.opened_at.store(0, Ordering::Relaxed);
            t.successes.store(0, Ordering::Relaxed);
            t.failures.store(0, Ordering::Relaxed);
            t.trips.store(0, Ordering::Relaxed);
            t.probes.store(0, Ordering::Relaxed);
        }
    }
}

/// Owns one instance of every available backend and serves each request
/// with the cheapest legal one under its [`CostModel`].  The Leon3
/// coprocessor model joined via [`with_leon3`](Self::with_leon3); the
/// remote worker-process pool — the "address mapping as a service" seam
/// — via [`with_remote`](Self::with_remote).
pub struct EngineSelector {
    software: SoftwareEngine,
    pow2: Pow2Engine,
    /// Shard pool, spawned lazily on the first request the cost model
    /// routes to it (a selector that never sees a big batch never
    /// spawns a thread).
    sharded: OnceLock<ShardedEngine<AutoEngine>>,
    shard_workers: usize,
    shard_threshold: usize,
    #[cfg(feature = "xla-unit")]
    xla: Option<super::XlaBatchEngine>,
    /// Minimum batch size worth a PJRT round-trip.
    #[cfg_attr(not(feature = "xla-unit"), allow(dead_code))]
    xla_threshold: usize,
    /// The Leon3 coprocessor model, installed via
    /// [`with_leon3`](Self::with_leon3); priced per request like every
    /// other backend once present.
    leon3: Option<Leon3Engine>,
    /// The remote worker-process pool (shared: one pool can serve many
    /// selectors, e.g. every core of a simulated machine).
    remote: Option<Arc<RemoteEngine>>,
    /// Minimum batch size eligible for the remote leg.
    remote_threshold: usize,
    /// Minimum increment-batch size worth inspecting for per-owner
    /// bucketing ([`increment_choosing`](Self::increment_choosing)).
    gather_threshold: usize,
    /// Counters behind the gather leg (`gather.*` stats lines).
    gather: GatherCounters,
    /// The vectorized software tier (always installed: it is pure host
    /// arithmetic, legal for every layout).
    simd: SimdEngine,
    /// Serial/vector cutover: batches below this stay scalar even if
    /// the per-pointer estimate says vectorize (loop setup dominates).
    simd_threshold: usize,
    /// Minimum batch size worth building a cache-blocked [`TilePlan`].
    plan_threshold: usize,
    /// Requests per planned tile.
    plan_tile: usize,
    /// Counters behind the vectorized tier (`simd.*` stats lines).
    simd_ctr: SimdCounters,
    /// Counters behind the batch planner (`plan.*` stats lines).
    plan_ctr: PlanCounters,
    cost: CostModel,
    /// Install-time calibrations, re-applied on every cost-model write.
    measured: MeasuredLegs,
    /// Requests served per [`EngineChoice`] (indexed by
    /// `EngineChoice::index`).
    hits: [AtomicU64; EngineChoice::COUNT],
    /// Per-tier health + breaker state behind the dispatch funnel.
    health: Health,
    /// Seeded fault injector consulted at the dispatch funnel
    /// ([`with_chaos`](Self::with_chaos)); never fires on the fallback
    /// re-serve, so the ladder always terminates.
    chaos: Option<Arc<FaultPlan>>,
}

impl EngineSelector {
    /// Default minimum batch size routed to the XLA unit (dispatch to
    /// PJRT costs tens of microseconds; small batches stay scalar).
    pub const DEFAULT_XLA_THRESHOLD: usize = 1024;

    /// Minimum batch size eligible for the shard pool.  The cost model
    /// still has to pick it; this floor keeps small-batch selection
    /// deterministic and free of pool bookkeeping.
    pub const DEFAULT_SHARD_THRESHOLD: usize = 8192;

    /// Minimum batch size eligible for the remote worker-process pool:
    /// the socket hop costs ~100 µs, so only batches big enough that
    /// the measured cost model *could* prefer it are even priced.
    pub const DEFAULT_REMOTE_THRESHOLD: usize = 1 << 16;

    /// Minimum batch size eligible for a **daemon-served** remote tier
    /// (`RemoteTier::connect`): with epoch sessions the steady-state
    /// request carries only `epoch + batch` — no ctx snapshot per frame
    /// — so the dispatch fee is smaller and batches a quarter the size
    /// of [`DEFAULT_REMOTE_THRESHOLD`](Self::DEFAULT_REMOTE_THRESHOLD)
    /// are worth pricing.
    pub const DEFAULT_DAEMON_THRESHOLD: usize = 1 << 14;

    /// Minimum increment-batch size the inspector/executor gather leg
    /// even looks at.  Below this the bucketing tax (and the extra
    /// per-bucket dispatches) cannot amortize; the default matches the
    /// width of a typical compiled gather window.
    /// [`with_gather_calibration`](Self::with_gather_calibration)
    /// re-derives it from this host's measured plan-setup cost.
    pub const DEFAULT_GATHER_THRESHOLD: usize = 8;

    /// Minimum batch size the vectorized tier competes at — the
    /// `PAR_THRESHOLD`-style serial/vector cutover.  Below a few lane
    /// widths the chunk-loop setup and SoA loads cost more than the
    /// divides they replace, so tiny batches stay on the scalar floor.
    pub const DEFAULT_SIMD_THRESHOLD: usize = 4 * SIMD_LANES;

    /// Minimum batch size worth building a cache-blocked [`TilePlan`]:
    /// two default tiles — below that the plan degenerates to a single
    /// tile and planning is pure overhead.
    pub const DEFAULT_PLAN_THRESHOLD: usize = 2 * L2_TILE_PTRS;

    /// Cap on the default worker-pool size (campaigns run many
    /// selector-owning runtimes concurrently).
    const MAX_DEFAULT_WORKERS: usize = 8;

    /// A selector with the host backends (software, pow2, lazily
    /// sharded) and default cost constants.
    pub fn new() -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(Self::MAX_DEFAULT_WORKERS);
        Self {
            software: SoftwareEngine,
            pow2: Pow2Engine,
            sharded: OnceLock::new(),
            shard_workers: workers,
            shard_threshold: Self::DEFAULT_SHARD_THRESHOLD,
            #[cfg(feature = "xla-unit")]
            xla: None,
            xla_threshold: Self::DEFAULT_XLA_THRESHOLD,
            leon3: None,
            remote: None,
            remote_threshold: Self::DEFAULT_REMOTE_THRESHOLD,
            gather_threshold: Self::DEFAULT_GATHER_THRESHOLD,
            gather: GatherCounters::default(),
            simd: SimdEngine,
            simd_threshold: Self::DEFAULT_SIMD_THRESHOLD,
            plan_threshold: Self::DEFAULT_PLAN_THRESHOLD,
            plan_tile: L2_TILE_PTRS,
            simd_ctr: SimdCounters::default(),
            plan_ctr: PlanCounters::default(),
            cost: CostModel::default(),
            measured: MeasuredLegs::default(),
            hits: std::array::from_fn(|_| AtomicU64::new(0)),
            health: Health::default(),
            chaos: None,
        }
    }

    /// Multiple of the cost-model estimate a dispatch may take before
    /// it counts as a deadline miss (generous: estimates are medians,
    /// hosts are noisy — only pathological stalls should miss).
    const DEADLINE_FACTOR: f64 = 32.0;

    /// Deadline floor in ns (scheduler jitter alone can cost
    /// milliseconds on a loaded host; never miss below this).
    const DEADLINE_FLOOR_NS: f64 = 10_000_000.0;

    /// Size of the shard pool (call before the pool's first use; a
    /// single worker disables sharding entirely).
    pub fn with_shard_workers(mut self, n: usize) -> Self {
        self.shard_workers = n.max(1);
        self
    }

    /// Route batches of at least `n` pointers through the shard-pool
    /// leg of the cost model.
    pub fn with_shard_threshold(mut self, n: usize) -> Self {
        self.shard_threshold = n.max(1);
        self
    }

    /// Route increment batches of at least `n` pointers through the
    /// inspector/executor gather leg (per-owner bucketing).  `n = 0`
    /// is clamped to 1; use `usize::MAX` to disable gathering.
    pub fn with_gather_threshold(mut self, n: usize) -> Self {
        self.gather_threshold = n.max(1);
        self
    }

    /// Measure this host's actual inspection cost
    /// ([`GatherPlan::calibrate`]) and derive the gather threshold from
    /// it: the per-pointer bucketing leg goes into the cost model, and
    /// the threshold is set where the plan's *fixed* setup cost
    /// amortizes below one software-translate per pointer — the same
    /// measured-not-guessed discipline as the Leon3/remote legs.
    pub fn with_gather_calibration(mut self) -> Self {
        let (bucket_ns_per_ptr, plan_setup_ns) = GatherPlan::calibrate();
        self.cost.gather_bucket_ns_per_ptr = bucket_ns_per_ptr;
        let floor = self.cost.software_ns_per_ptr.max(1e-9);
        self.gather_threshold = ((plan_setup_ns / floor).ceil() as usize)
            .max(Self::DEFAULT_GATHER_THRESHOLD);
        self
    }

    /// The minimum increment-batch size the gather leg inspects.
    pub fn gather_threshold(&self) -> usize {
        self.gather_threshold
    }

    /// Snapshot the gather-leg counters (plans executed, pointers
    /// bucketed, eligible batches served direct).
    pub fn gather_stats(&self) -> GatherStats {
        self.gather.snapshot()
    }

    /// Measure this host's actual vectorized per-pointer cost
    /// ([`SimdEngine::calibrate`]) and install it as the simd leg of
    /// the cost model — the same measured-not-guessed discipline as the
    /// Leon3/remote/gather legs.  The measurement is recorded and
    /// survives any later [`with_cost_model`](Self::with_cost_model).
    pub fn with_simd_calibration(mut self) -> Self {
        let ns_per_ptr = SimdEngine::calibrate();
        self.measured.simd = Some(ns_per_ptr);
        self.reapply_measured();
        self
    }

    /// Force the simd leg's per-pointer price (recorded like a
    /// measurement, so later cost-model writes keep it) — how tests and
    /// the resilience bench pin the vector tier's position in the
    /// argmin.
    pub fn with_simd_cost(mut self, ns_per_ptr: f64) -> Self {
        self.measured.simd = Some(ns_per_ptr);
        self.reapply_measured();
        self
    }

    /// Route batches of at least `n` pointers through the vectorized
    /// leg of the cost model (`n = 0` is clamped to 1; `usize::MAX`
    /// disables the tier).
    pub fn with_simd_threshold(mut self, n: usize) -> Self {
        self.simd_threshold = n.max(1);
        self
    }

    /// The serial/vector cutover currently in force.
    pub fn simd_threshold(&self) -> usize {
        self.simd_threshold
    }

    /// Snapshot the vectorized-tier counters (batches served, lane vs
    /// scalar-tail pointers).
    pub fn simd_stats(&self) -> SimdStats {
        self.simd_ctr.snapshot()
    }

    /// Build cache-blocked [`TilePlan`]s for batches of at least `n`
    /// pointers (`n = 0` is clamped to 1; `usize::MAX` disables the
    /// planner).
    pub fn with_plan_threshold(mut self, n: usize) -> Self {
        self.plan_threshold = n.max(1);
        self
    }

    /// The planner engagement threshold currently in force.
    pub fn plan_threshold(&self) -> usize {
        self.plan_threshold
    }

    /// Requests per planned tile (clamped to at least 1; default
    /// [`L2_TILE_PTRS`]).
    pub fn with_plan_tile(mut self, tile_ptrs: usize) -> Self {
        self.plan_tile = tile_ptrs.max(1);
        self
    }

    /// Snapshot the planner counters (plans built, tiles dispatched,
    /// planned pointers, single-tile fallbacks).
    pub fn plan_stats(&self) -> PlanStats {
        self.plan_ctr.snapshot()
    }

    /// Replace the tunable cost constants (e.g. from a calibration
    /// run).  Backend legs that were **measured at install time**
    /// ([`with_leon3`](Self::with_leon3),
    /// [`with_remote`](Self::with_remote)) are re-applied on top, so
    /// builder order does not matter — a measurement can only be
    /// discarded by installing the backend with its `*_uncalibrated`
    /// variant, which records none.
    pub fn with_cost_model(mut self, cost: CostModel) -> Self {
        self.cost = cost;
        self.reapply_measured();
        self
    }

    /// Write the install-time calibrations back over the current cost
    /// model (called after every cost-model replacement).
    fn reapply_measured(&mut self) {
        if let Some((ns_per_ptr, dispatch_ns)) = self.measured.leon3 {
            self.cost.leon3_ns_per_ptr = ns_per_ptr;
            self.cost.leon3_dispatch_ns = dispatch_ns;
        }
        if let Some((ns_per_ptr, dispatch_ns)) = self.measured.remote {
            self.cost.remote_ns_per_ptr = ns_per_ptr;
            self.cost.remote_dispatch_ns = dispatch_ns;
        }
        if let Some(ns_per_ptr) = self.measured.simd {
            self.cost.simd_ns_per_ptr = ns_per_ptr;
        }
    }

    /// Install the XLA batch backend.
    #[cfg(feature = "xla-unit")]
    pub fn with_xla(mut self, engine: super::XlaBatchEngine) -> Self {
        self.xla = Some(engine);
        self
    }

    /// Route batches of at least `n` pointers to the XLA unit.
    #[cfg(feature = "xla-unit")]
    pub fn with_xla_threshold(mut self, n: usize) -> Self {
        self.xla_threshold = n;
        self
    }

    /// Is the XLA batch backend installed?
    #[cfg(feature = "xla-unit")]
    pub fn has_xla(&self) -> bool {
        self.xla.is_some()
    }

    /// Install the Leon3 coprocessor model and **calibrate** its
    /// cost-model legs: [`Leon3Engine::calibrate`] measures this
    /// host's actual per-pointer replay cost *and* per-batch dispatch
    /// fee, so the argmin prices the hardware path with measured
    /// rather than guessed coefficients.  (With honest numbers the
    /// replay never beats the shift/mask arithmetic — installing it
    /// serves reporting and differential validation; override the cost
    /// model to emulate real-silicon pricing.)  The measurement is
    /// recorded and survives any later
    /// [`with_cost_model`](Self::with_cost_model) in either order.
    pub fn with_leon3(mut self, engine: Leon3Engine) -> Self {
        let (ns_per_ptr, dispatch_ns) = engine.calibrate();
        self.measured.leon3 = Some((ns_per_ptr, dispatch_ns));
        self.leon3 = Some(engine);
        self.reapply_measured();
        self
    }

    /// Install the Leon3 backend without the calibration run, keeping
    /// whatever `leon3_*` constants the current [`CostModel`] holds
    /// (no measurement is recorded, so a later cost-model write fully
    /// controls the legs — this is how tests force silicon-like
    /// pricing).
    pub fn with_leon3_uncalibrated(mut self, engine: Leon3Engine) -> Self {
        self.leon3 = Some(engine);
        self
    }

    /// Is the Leon3 coprocessor model installed?
    pub fn has_leon3(&self) -> bool {
        self.leon3.is_some()
    }

    /// Spawn an `n`-process remote pool ([`RemoteEngine::spawn`]) and
    /// install it with **measured** cost-model legs from a
    /// [`RemoteEngine::calibrate`] round-trip — like
    /// [`with_leon3`](Self::with_leon3), the argmin prices the socket
    /// hop with this host's real numbers (on one machine it rarely
    /// wins; the tier exists for the scale-out seam).  The measurement
    /// survives any later [`with_cost_model`](Self::with_cost_model).
    pub fn with_remote(self, workers: usize) -> Result<Self, EngineError> {
        let engine = Arc::new(RemoteEngine::spawn(workers)?);
        let (ns_per_ptr, dispatch_ns) = engine.calibrate()?;
        let mut sel = self;
        // keep any threshold configured before this call — builder
        // order must not silently reset it
        let threshold = sel.remote_threshold;
        sel.set_remote(engine, ns_per_ptr, dispatch_ns, threshold);
        Ok(sel)
    }

    /// Install an already-spawned remote pool with explicit pricing
    /// legs + threshold (what `RemoteTier::apply` calls; the legs are
    /// recorded like a measurement so later cost-model writes keep
    /// them).
    pub fn set_remote(
        &mut self,
        engine: Arc<RemoteEngine>,
        ns_per_ptr: f64,
        dispatch_ns: f64,
        threshold: usize,
    ) {
        self.measured.remote = Some((ns_per_ptr, dispatch_ns));
        self.remote = Some(engine);
        self.remote_threshold = threshold.max(1);
        self.reapply_measured();
    }

    /// Route batches of at least `n` pointers through the remote leg
    /// of the cost model.
    pub fn with_remote_threshold(mut self, n: usize) -> Self {
        self.remote_threshold = n.max(1);
        self
    }

    /// Is the remote worker-process pool installed?
    pub fn has_remote(&self) -> bool {
        self.remote.is_some()
    }

    /// The minimum batch size the remote leg is priced at.
    pub fn remote_threshold(&self) -> usize {
        self.remote_threshold
    }

    /// The cost constants currently in force.
    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// How many pool workers a request of `n` items can actually keep
    /// busy (the pool only fans out to `n / min_shard_len` shards).
    fn effective_workers(&self, n: usize) -> usize {
        (n / ShardedEngine::<AutoEngine>::DEFAULT_MIN_SHARD_LEN)
            .clamp(1, self.shard_workers)
    }

    /// Allocation-free argmin over the legal backends for one request.
    /// `walk` prices steps off the O(1) stepper instead of the batch
    /// translate path.
    fn argmin(&self, layout: &ArrayLayout, n: usize, walk: bool) -> EngineChoice {
        let workers = self.effective_workers(n);
        let price = |choice: EngineChoice| {
            if walk {
                self.cost.estimate_walk(choice, n, workers)
            } else {
                self.cost.estimate(choice, layout, n, workers)
            }
        };
        // Quarantine = re-running the argmin over the surviving tiers:
        // every leg below simply drops out while its breaker is open.
        // `SoftwareEngine` is the unconditional floor — it supports
        // every layout and is never quarantined, so the argmin always
        // has a survivor.
        let scalar = self.scalar_choice(layout);
        let mut best = (scalar, price(scalar));
        // The vectorized software tier: pure host arithmetic, legal for
        // every layout, but never the *fallback* floor (the ladder ends
        // on the scalar engines) and never priced for walks (the O(1)
        // stepper has no lanes to fill).
        if !walk
            && n >= self.simd_threshold
            && self.health.admit(EngineChoice::Simd)
        {
            let ns = price(EngineChoice::Simd);
            if ns < best.1 {
                best = (EngineChoice::Simd, ns);
            }
        }
        if self.shard_workers > 1
            && n >= self.shard_threshold
            && self.health.admit(EngineChoice::Sharded)
        {
            let ns = price(EngineChoice::Sharded);
            if ns < best.1 {
                best = (EngineChoice::Sharded, ns);
            }
        }
        #[cfg(feature = "xla-unit")]
        if let Some(x) = &self.xla {
            if n >= self.xla_threshold
                && x.supports(layout)
                && self.health.admit(EngineChoice::XlaBatch)
            {
                let ns = price(EngineChoice::XlaBatch);
                if ns < best.1 {
                    best = (EngineChoice::XlaBatch, ns);
                }
            }
        }
        if let Some(l3) = &self.leon3 {
            if l3.supports(layout) && self.health.admit(EngineChoice::Leon3) {
                let ns = price(EngineChoice::Leon3);
                if ns < best.1 {
                    best = (EngineChoice::Leon3, ns);
                }
            }
        }
        if self.remote.is_some()
            && n >= self.remote_threshold
            && self.health.admit(EngineChoice::Remote)
        {
            // the workers run AutoEngine: every layout is legal
            let ns = price(EngineChoice::Remote);
            if ns < best.1 {
                best = (EngineChoice::Remote, ns);
            }
        }
        best.0
    }

    /// The scalar floor for `layout`: the pow2 fast path while its
    /// breaker admits it, software Algorithm 1 otherwise (software is
    /// never quarantined — the ladder must terminate).
    fn scalar_choice(&self, layout: &ArrayLayout) -> EngineChoice {
        if layout.hw_supported() && self.health.admit(EngineChoice::Pow2) {
            EngineChoice::Pow2
        } else {
            EngineChoice::Software
        }
    }

    /// The backend the cost model picks for `layout` at `batch_len`.
    ///
    /// # Examples
    ///
    /// ```
    /// use pgas_hw::engine::{EngineChoice, EngineSelector};
    /// use pgas_hw::sptr::ArrayLayout;
    ///
    /// // A single-worker selector keeps the paper's shift/mask fast
    /// // path on pow2 geometry (no vector lane beats one shift)...
    /// let sel = EngineSelector::new().with_shard_workers(1);
    /// assert_eq!(
    ///     sel.choice(&ArrayLayout::new(4, 8, 4), 64),
    ///     EngineChoice::Pow2
    /// );
    /// // ...routes batched work on CG's non-pow2 w_tmp struct to the
    /// // vectorized reciprocal lanes...
    /// assert_eq!(
    ///     sel.choice(&ArrayLayout::new(1, 56016, 8), 64),
    ///     EngineChoice::Simd
    /// );
    /// // ...and keeps scalar software Algorithm 1 below the
    /// // serial/vector cutover.
    /// assert_eq!(
    ///     sel.choice(&ArrayLayout::new(1, 56016, 8), 4),
    ///     EngineChoice::Software
    /// );
    /// ```
    pub fn choice(&self, layout: &ArrayLayout, batch_len: usize) -> EngineChoice {
        self.argmin(layout, batch_len, false)
    }

    /// The backend the cost model picks for a `steps`-long walk of
    /// `layout` (walks step O(1) via the cursor, so they shard — or go
    /// to the XLA unit — only at much larger sizes than translates).
    pub fn choice_walk(&self, layout: &ArrayLayout, steps: usize) -> EngineChoice {
        self.argmin(layout, steps, true)
    }

    /// The shard pool, spawned on first use.
    fn sharded_pool(&self) -> &ShardedEngine<AutoEngine> {
        self.sharded
            .get_or_init(|| ShardedEngine::new(AutoEngine, self.shard_workers))
    }

    fn engine_for(&self, choice: EngineChoice) -> &dyn AddressEngine {
        match choice {
            EngineChoice::Software => &self.software,
            EngineChoice::Pow2 => &self.pow2,
            EngineChoice::Sharded => self.sharded_pool(),
            #[cfg(feature = "xla-unit")]
            EngineChoice::XlaBatch => {
                self.xla.as_ref().expect("choice() returned XlaBatch without a unit")
            }
            #[cfg(not(feature = "xla-unit"))]
            EngineChoice::XlaBatch => &self.software,
            EngineChoice::Leon3 => self
                .leon3
                .as_ref()
                .expect("choice() returned Leon3 without the model installed"),
            EngineChoice::Remote => self
                .remote
                .as_deref()
                .expect("choice() returned Remote without the pool installed"),
            EngineChoice::Simd => &self.simd,
        }
    }

    /// Pick the cheapest legal backend for `layout` at `batch_len`.
    pub fn select(&self, layout: &ArrayLayout, batch_len: usize) -> &dyn AddressEngine {
        self.engine_for(self.choice(layout, batch_len))
    }

    fn record(&self, choice: EngineChoice) {
        self.hits[choice.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Install a seeded fault injector at the dispatch funnel: each
    /// passthrough draws from `plan` before running its chosen backend
    /// (errors are returned unrun, spikes are billed against the
    /// deadline).  The fallback re-serve never draws, so injected
    /// faults are always absorbed — `--chaos SEED` ends here.
    pub fn with_chaos(mut self, plan: Arc<FaultPlan>) -> Self {
        self.chaos = Some(plan);
        self
    }

    /// In-place form of [`with_chaos`](Self::with_chaos) (the CPU
    /// pipelines own their selector by value).
    pub fn set_chaos(&mut self, plan: Arc<FaultPlan>) {
        self.chaos = Some(plan);
    }

    /// Is a chaos plan installed?
    pub fn has_chaos(&self) -> bool {
        self.chaos.is_some()
    }

    /// Snapshot the degradation ladder (per-tier health, breaker
    /// states, fallback/deadline/injection totals).
    pub fn health_stats(&self) -> HealthStats {
        self.health.snapshot()
    }

    /// Zero the health record and close every breaker (e.g. between
    /// campaign phases, or per-iteration in the resilience bench).
    pub fn reset_health(&self) {
        self.health.reset();
    }

    /// One guarded trip through the funnel: draw any planned chaos,
    /// time the chosen backend against its cost-model deadline, feed
    /// the outcome to the health record, and transparently re-serve a
    /// transient failure ([`EngineError::Backend`]) or deadline miss
    /// via the fallback ladder.  Returns the choice that actually
    /// produced the output.  Structural refusals (`UnsupportedLayout`,
    /// `TableTooSmall`, `LengthMismatch`) propagate unchanged — they
    /// are deterministic caller errors a fallback would only mask.
    fn dispatch(
        &self,
        primary: EngineChoice,
        layout: &ArrayLayout,
        n: usize,
        walk: bool,
        run: &mut dyn FnMut(&dyn AddressEngine) -> Result<(), EngineError>,
    ) -> Result<EngineChoice, EngineError> {
        let clock = self.health.dispatches.fetch_add(1, Ordering::Relaxed) + 1;
        self.record(primary);
        let workers = self.effective_workers(n);
        let estimate = if walk {
            self.cost.estimate_walk(primary, n, workers)
        } else {
            self.cost.estimate(primary, layout, n, workers)
        };
        let deadline_ns =
            Self::DEADLINE_FACTOR * estimate + Self::DEADLINE_FLOOR_NS;
        let fault = self.chaos.as_deref().and_then(|p| p.engine_fault());
        if fault.is_some() {
            self.health.injected_faults.fetch_add(1, Ordering::Relaxed);
        }
        let mut billed_ns = 0.0;
        let outcome = match fault {
            Some(EngineFault::Error) => Err(EngineError::Backend(format!(
                "chaos: injected fault on `{}`",
                primary.name()
            ))),
            other => {
                if let Some(EngineFault::Spike(ns)) = other {
                    billed_ns += ns as f64;
                }
                let t0 = Instant::now();
                let r = run(self.engine_for(primary));
                billed_ns += t0.elapsed().as_nanos() as f64;
                r
            }
        };
        match outcome {
            Ok(()) if billed_ns <= deadline_ns => {
                self.health.on_success(primary);
                if primary == EngineChoice::Simd {
                    let tail = (n % SIMD_LANES) as u64;
                    self.simd_ctr.batches.fetch_add(1, Ordering::Relaxed);
                    self.simd_ctr
                        .lane_ptrs
                        .fetch_add(n as u64 - tail, Ordering::Relaxed);
                    self.simd_ctr.tail_ptrs.fetch_add(tail, Ordering::Relaxed);
                }
                return Ok(primary);
            }
            Ok(()) => {
                // over deadline: the result is valid but the tier is
                // sick — health-fail it and re-serve below so callers
                // get the bounded-latency tier from here on
                self.health.deadline_misses.fetch_add(1, Ordering::Relaxed);
                self.health.on_failure(primary, clock);
            }
            Err(EngineError::Backend(_)) => {
                self.health.on_failure(primary, clock);
            }
            Err(e) => return Err(e),
        }
        // The fallback ladder (chaos- and deadline-exempt, so it always
        // terminates): the sharded pool where the batch warrants it and
        // it is not the tier that just failed, else the scalar floor.
        self.health.fallback_runs.fetch_add(1, Ordering::Relaxed);
        if primary != EngineChoice::Sharded
            && self.shard_workers > 1
            && n >= self.shard_threshold
        {
            if run(self.engine_for(EngineChoice::Sharded)).is_ok() {
                self.health.on_success(EngineChoice::Sharded);
                return Ok(EngineChoice::Sharded);
            }
            self.health.on_failure(EngineChoice::Sharded, clock);
            self.health.fallback_runs.fetch_add(1, Ordering::Relaxed);
        }
        let scalar = self.scalar_choice(layout);
        run(self.engine_for(scalar))?;
        self.health.on_success(scalar);
        Ok(scalar)
    }

    /// Requests served per backend through the selector's passthroughs
    /// since construction (or the last [`reset_hits`](Self::reset_hits))
    /// — the actual backend mix, archived by
    /// `coordinator::engine_report`.
    pub fn hit_counts(&self) -> [(EngineChoice, u64); EngineChoice::COUNT] {
        EngineChoice::ALL
            .map(|c| (c, self.hits[c.index()].load(Ordering::Relaxed)))
    }

    /// Zero every hit counter (e.g. between campaign phases).
    pub fn reset_hits(&self) {
        for h in &self.hits {
            h.store(0, Ordering::Relaxed);
        }
    }

    // ---- convenience passthroughs (select + guard + count per call):
    // every one runs the argmin once, then serves through the guarded
    // dispatch funnel (health, breaker, deadline, fallback) ----

    /// Build a cache-blocked plan for one over-threshold batch: tally
    /// and return it when it actually tiles (≥ 2 tiles), count the
    /// degenerate single-tile case as a fallback and return `None`.
    fn tile_plan(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
    ) -> Result<Option<TilePlan>, EngineError> {
        let plan = TilePlan::from_batch(ctx, batch, self.plan_tile)?;
        if plan.tile_count() < 2 {
            self.plan_ctr.fallback.fetch_add(1, Ordering::Relaxed);
            return Ok(None);
        }
        self.plan_ctr.plans.fetch_add(1, Ordering::Relaxed);
        self.plan_ctr
            .tiles
            .fetch_add(plan.tile_count() as u64, Ordering::Relaxed);
        self.plan_ctr
            .planned_ptrs
            .fetch_add(plan.len() as u64, Ordering::Relaxed);
        Ok(Some(plan))
    }

    pub fn translate(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        let n = batch.len();
        if n >= self.plan_threshold {
            // cache-blocked leg: tile, affinity-sort, dispatch the plan
            // through the same guarded funnel — the chosen tier's
            // `translate_planned` runs tiles cache-resident (or shards
            // over whole tile groups), bit-identical to the direct path
            if let Some(plan) = self.tile_plan(ctx, batch)? {
                let choice = self.choice(&ctx.layout, n);
                return self
                    .dispatch(choice, &ctx.layout, n, false, &mut |e| {
                        e.translate_planned(ctx, batch, &plan, out)
                    })
                    .map(|_| ());
            }
        }
        let choice = self.choice(&ctx.layout, n);
        self.dispatch(choice, &ctx.layout, n, false, &mut |e| {
            e.translate(ctx, batch, out)
        })
        .map(|_| ())
    }

    pub fn increment(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        self.increment_choosing(ctx, batch, out).map(|_| ())
    }

    /// [`increment`](Self::increment) that also reports which backend
    /// served the request — under degradation that is the *fallback*
    /// tier, not the argmin pick, so telemetry stays honest.  The
    /// argmin runs **once**; callers tallying their own telemetry (the
    /// CPU pipelines' per-window `EngineMix`) use this instead of a
    /// separate `choice()` + `increment()` pair.
    pub fn increment_choosing(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<EngineChoice, EngineError> {
        if batch.len() >= self.gather_threshold {
            // inspector/executor leg: bucket by owner, one aggregated
            // dispatch per owner, splice back in request order.
            // Inspection refusals (frame-cap overflow, SoA corruption)
            // propagate loudly — they are planning errors, not
            // transient faults.
            let plan = GatherPlan::from_batch(ctx, batch)?;
            if plan.bucket_count() >= 2 {
                return self.increment_planned(ctx, &plan, out);
            }
            // single-owner after inspection: bucketing would only add
            // copies; record the decision and serve direct
            self.gather.fallback.fetch_add(1, Ordering::Relaxed);
        }
        if batch.len() >= self.plan_threshold {
            // cache-blocked leg for the big single-owner (or
            // sub-gather-threshold) batches the inspector left behind
            if let Some(plan) = self.tile_plan(ctx, batch)? {
                let choice = self.choice(&ctx.layout, batch.len());
                return self.dispatch(
                    choice,
                    &ctx.layout,
                    batch.len(),
                    false,
                    &mut |e| e.increment_planned(ctx, batch, &plan, out),
                );
            }
        }
        let choice = self.choice(&ctx.layout, batch.len());
        self.dispatch(choice, &ctx.layout, batch.len(), false, &mut |e| {
            e.increment(ctx, batch, out)
        })
    }

    /// Serve one inspected multi-owner batch: every per-owner bucket
    /// goes through the full guarded dispatch funnel independently
    /// (argmin at the bucket's size, chaos draw, deadline, fallback
    /// ladder), then the plan splices results back into request order —
    /// bit-identical to the direct path.  Returns the backend that
    /// served the most pointers, the honest headline for the caller's
    /// `EngineMix` tally.
    fn increment_planned(
        &self,
        ctx: &EngineCtx,
        plan: &GatherPlan,
        out: &mut Vec<SharedPtr>,
    ) -> Result<EngineChoice, EngineError> {
        self.gather.plans.fetch_add(1, Ordering::Relaxed);
        self.gather
            .bucketed_ptrs
            .fetch_add(plan.len() as u64, Ordering::Relaxed);
        let mut dominant = (self.scalar_choice(&ctx.layout), 0usize);
        plan.execute_increment_with(out, &mut |bucket, scratch| {
            let choice = self.choice(&ctx.layout, bucket.len());
            let served = self.dispatch(
                choice,
                &ctx.layout,
                bucket.len(),
                false,
                &mut |e| e.increment(ctx, bucket, scratch),
            )?;
            if bucket.len() > dominant.1 {
                dominant = (served, bucket.len());
            }
            Ok(())
        })?;
        Ok(dominant.0)
    }

    pub fn walk(
        &self,
        ctx: &EngineCtx,
        start: SharedPtr,
        inc: u64,
        steps: usize,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        let choice = self.choice_walk(&ctx.layout, steps);
        self.dispatch(choice, &ctx.layout, steps, true, &mut |e| {
            e.walk(ctx, start, inc, steps, out)
        })
        .map(|_| ())
    }

    pub fn translate_one(
        &self,
        ctx: &EngineCtx,
        ptr: SharedPtr,
        inc: u64,
    ) -> Result<(SharedPtr, u64, Locality), EngineError> {
        let choice = self.choice(&ctx.layout, 1);
        let mut res = None;
        self.dispatch(choice, &ctx.layout, 1, false, &mut |e| {
            res = Some(e.translate_one(ctx, ptr, inc)?);
            Ok(())
        })?;
        Ok(res.expect("dispatch succeeded without a result"))
    }
}

impl Default for EngineSelector {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sptr::BaseTable;

    #[test]
    fn selection_mirrors_the_compiler_variant_choice() {
        // A single-worker selector degenerates to the paper's fixed
        // policy: hardware fast path when pow2, software otherwise.
        let sel = EngineSelector::new().with_shard_workers(1);
        assert_eq!(sel.choice(&ArrayLayout::new(4, 4, 4), 1), EngineChoice::Pow2);
        assert_eq!(
            sel.choice(&ArrayLayout::new(64, 8, 16), 1 << 20),
            EngineChoice::Pow2
        );
        // the CG w/w_tmp case (elemsize 56016): the general path — now
        // vectorized reciprocal lanes once the batch fills them
        assert_eq!(
            sel.choice(&ArrayLayout::new(1, 56016, 8), 1 << 20),
            EngineChoice::Simd
        );
        assert_eq!(sel.select(&ArrayLayout::new(1, 56016, 8), 4).name(), "software");
        assert_eq!(sel.select(&ArrayLayout::new(4, 4, 4), 4).name(), "pow2");
    }

    #[test]
    fn cost_model_routes_big_batches_to_the_shard_pool() {
        let sel = EngineSelector::new().with_shard_workers(8);
        let pow2 = ArrayLayout::new(64, 8, 16);
        let soft = ArrayLayout::new(1, 56016, 8);
        // tiny batches stay scalar regardless of layout
        assert_eq!(sel.choice(&pow2, 8), EngineChoice::Pow2);
        assert_eq!(sel.choice(&soft, 8), EngineChoice::Software);
        // huge batches amortize the scatter/gather fee
        assert_eq!(sel.choice(&pow2, 1 << 20), EngineChoice::Sharded);
        assert_eq!(sel.choice(&soft, 1 << 20), EngineChoice::Sharded);
        // just past the threshold the fee still dominates the cheap
        // pow2 path; the expensive software path is undercut by the
        // vectorized lanes before the pool fee can amortize
        let n = EngineSelector::DEFAULT_SHARD_THRESHOLD;
        assert_eq!(sel.choice(&pow2, n), EngineChoice::Pow2);
        assert_eq!(sel.choice(&soft, n), EngineChoice::Simd);
    }

    #[test]
    fn walks_are_priced_off_the_stepper() {
        let sel = EngineSelector::new().with_shard_workers(8);
        let soft = ArrayLayout::new(1, 56016, 8);
        // a translate batch of this size leaves the scalar floor (the
        // vector lanes undercut 12 ns/ptr software)...
        assert_eq!(sel.choice(&soft, 16384), EngineChoice::Simd);
        // ...but a walk of the same length is O(1)/step inline and
        // stays on the scalar stepper
        assert_eq!(sel.choice_walk(&soft, 16384), EngineChoice::Software);
        // truly huge walks still amortize the pool fee
        assert_eq!(sel.choice_walk(&soft, 1 << 20), EngineChoice::Sharded);
    }

    #[test]
    fn sharded_passthrough_is_bit_identical_and_counted() {
        let sel = EngineSelector::new()
            .with_shard_workers(3)
            .with_shard_threshold(64)
            // pin the argmin on the pool: this test exercises the
            // sharded leg, not the serial/vector cutover
            .with_simd_threshold(usize::MAX);
        let layout = ArrayLayout::new(1, 56016, 8); // software inner
        let table = BaseTable::regular(8, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 2).unwrap();
        // 16384 × 12ns software vs 40µs + 16384 × 12ns / 3 workers:
        // the pool wins the argmin.
        let mut batch = PtrBatch::new();
        for i in 0..16384u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 7), i % 97);
        }
        assert_eq!(sel.choice(&layout, batch.len()), EngineChoice::Sharded);
        let (mut via_sel, mut direct) = (BatchOut::new(), BatchOut::new());
        sel.translate(&ctx, &batch, &mut via_sel).unwrap();
        SoftwareEngine.translate(&ctx, &batch, &mut direct).unwrap();
        assert_eq!(via_sel, direct);
        let hits = sel.hit_counts();
        assert_eq!(hits[EngineChoice::Sharded.index()].1, 1);
        assert_eq!(hits[EngineChoice::Software.index()].1, 0);
        sel.reset_hits();
        assert!(sel.hit_counts().iter().all(|&(_, n)| n == 0));
    }

    #[test]
    fn passthroughs_dispatch_to_the_selected_backend() {
        let sel = EngineSelector::new();
        let layout = ArrayLayout::new(4, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut out = BatchOut::new();
        sel.walk(&ctx, SharedPtr::NULL, 1, 12, &mut out).unwrap();
        assert_eq!(out.len(), 12);
        for (i, p) in out.ptrs.iter().enumerate() {
            assert_eq!(*p, SharedPtr::for_index(&layout, 0, i as u64));
        }
        let (q, sysva, _) = sel.translate_one(&ctx, SharedPtr::NULL, 5).unwrap();
        assert_eq!(q, SharedPtr::for_index(&layout, 0, 5));
        assert_eq!(sysva, table.base(q.thread) + q.va);
        // both requests were recorded against the pow2 scalar path
        let hits = sel.hit_counts();
        assert_eq!(hits[EngineChoice::Pow2.index()].1, 2);
    }

    #[test]
    fn leon3_joins_the_priced_matrix_only_when_installed() {
        let plain = EngineSelector::new().with_shard_workers(1);
        assert!(!plain.has_leon3());
        // install the coprocessor model and force its cost legs to zero
        // so the argmin must pick it wherever the hardware gate allows
        let sel = EngineSelector::new()
            .with_shard_workers(1)
            .with_leon3_uncalibrated(Leon3Engine::new())
            .with_cost_model(CostModel {
                leon3_ns_per_ptr: 0.0,
                leon3_dispatch_ns: 0.0,
                ..CostModel::default()
            });
        let pow2 = ArrayLayout::new(4, 8, 4);
        let soft = ArrayLayout::new(1, 56016, 8);
        assert_eq!(sel.choice(&pow2, 64), EngineChoice::Leon3);
        assert_eq!(sel.choice_walk(&pow2, 64), EngineChoice::Leon3);
        // the hardware gate still overrides price: non-pow2 falls to
        // the general-path tiers (vectorized at this batch size)
        assert_eq!(sel.choice(&soft, 64), EngineChoice::Simd);
        assert_eq!(sel.choice(&soft, 4), EngineChoice::Software);
        // served through the selector: bit-identical and counted
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(pow2, &table, 1).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..64 {
            batch.push(SharedPtr::for_index(&pow2, 0, i * 3), i);
        }
        let (mut via, mut direct) = (BatchOut::new(), BatchOut::new());
        sel.translate(&ctx, &batch, &mut via).unwrap();
        SoftwareEngine.translate(&ctx, &batch, &mut direct).unwrap();
        assert_eq!(via, direct);
        assert_eq!(sel.hit_counts()[EngineChoice::Leon3.index()].1, 1);
    }

    #[test]
    fn with_leon3_installs_measured_coefficients() {
        let sel = EngineSelector::new().with_leon3(Leon3Engine::new());
        assert!(sel.has_leon3());
        assert!(sel.cost_model().leon3_ns_per_ptr >= 1.0);
        // honestly-priced instruction replay stays out of the hot path
        assert_eq!(
            sel.choice(&ArrayLayout::new(4, 8, 4), 64),
            EngineChoice::Pow2
        );
    }

    #[test]
    fn cost_model_order_cannot_discard_leon3_calibration() {
        // Regression: with_cost_model used to overwrite the measured
        // leon3 legs when called after with_leon3.  A sentinel model
        // must lose to the measurement in *both* orders, while its
        // unmeasured legs stick.
        let sentinel = CostModel {
            leon3_ns_per_ptr: 7777.0,
            leon3_dispatch_ns: 8888.0,
            software_ns_per_ptr: 99.0,
            ..CostModel::default()
        };
        let before = EngineSelector::new()
            .with_cost_model(sentinel)
            .with_leon3(Leon3Engine::new());
        let after = EngineSelector::new()
            .with_leon3(Leon3Engine::new())
            .with_cost_model(sentinel);
        for (label, sel) in [("cost-first", &before), ("leon3-first", &after)] {
            let cm = sel.cost_model();
            assert_ne!(cm.leon3_ns_per_ptr, 7777.0, "{label}: measurement lost");
            assert_ne!(cm.leon3_dispatch_ns, 8888.0, "{label}: measurement lost");
            assert_eq!(cm.software_ns_per_ptr, 99.0, "{label}: override lost");
        }
        // the uncalibrated install records nothing: the sentinel rules
        let forced = EngineSelector::new()
            .with_leon3_uncalibrated(Leon3Engine::new())
            .with_cost_model(sentinel);
        assert_eq!(forced.cost_model().leon3_ns_per_ptr, 7777.0);
    }

    #[test]
    fn remote_leg_is_priced_but_gated_by_install_and_threshold() {
        // Without a pool installed the argmin must never return Remote
        // no matter how cheap the legs claim to be.
        let sel = EngineSelector::new()
            .with_shard_workers(1)
            .with_cost_model(CostModel {
                remote_ns_per_ptr: 0.0,
                remote_dispatch_ns: 0.0,
                ..CostModel::default()
            });
        assert!(!sel.has_remote());
        assert_eq!(sel.choice(&ArrayLayout::new(4, 8, 4), 1 << 20), EngineChoice::Pow2);
        // the cost shape itself: fee + n * marginal
        let cm = CostModel::default();
        let n = 1 << 20;
        let est = cm.estimate(EngineChoice::Remote, &ArrayLayout::new(4, 8, 4), n, 1);
        assert_eq!(est, cm.remote_dispatch_ns + n as f64 * cm.remote_ns_per_ptr);
        // (selector-level remote routing needs live worker processes;
        // rust/tests/remote_engine.rs covers it end to end.)
    }

    #[test]
    fn injected_faults_are_absorbed_by_the_fallback_ladder() {
        use super::super::fault::FaultSpec;
        // Every dispatch draws an injected error, yet no error may ever
        // reach the caller and outputs stay bit-identical.
        let plan = Arc::new(FaultPlan::new(FaultSpec {
            error: 1.0,
            ..FaultSpec::quiet(0xC0FFEE)
        }));
        let sel = EngineSelector::new()
            .with_shard_workers(1)
            .with_chaos(Arc::clone(&plan));
        let layout = ArrayLayout::new(4, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 3), i);
        }
        let (mut via, mut direct) = (BatchOut::new(), BatchOut::new());
        for _ in 0..8 {
            sel.translate(&ctx, &batch, &mut via).unwrap();
        }
        SoftwareEngine.translate(&ctx, &batch, &mut direct).unwrap();
        assert_eq!(via, direct);
        let h = sel.health_stats();
        assert_eq!(h.dispatches, 8);
        assert_eq!(h.fallback_runs, 8, "every dispatch was re-served");
        assert_eq!(h.injected_faults, 8);
        assert!(h.failures() >= 8);
        // the pow2 primary tripped its breaker after TRIP_CONSEC
        // failures, so the scalar floor degraded to software
        assert_eq!(h.tiers[EngineChoice::Pow2.index()].state, BreakerState::Open);
        assert!(h.tiers[EngineChoice::Pow2.index()].trips >= 1);
        assert_eq!(sel.scalar_choice(&layout), EngineChoice::Software);
    }

    #[test]
    fn breaker_reopens_after_cooldown_and_recovers_on_a_clean_probe() {
        use super::super::fault::FaultSpec;
        let layout = ArrayLayout::new(4, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        batch.push(SharedPtr::NULL, 1);
        let mut out = BatchOut::new();
        // trip the pow2 breaker with an always-fault plan...
        let mut sel = EngineSelector::new()
            .with_shard_workers(1)
            .with_chaos(Arc::new(FaultPlan::new(FaultSpec {
                error: 1.0,
                ..FaultSpec::quiet(1)
            })));
        for _ in 0..Health::TRIP_CONSEC {
            sel.translate(&ctx, &batch, &mut out).unwrap();
        }
        assert_eq!(
            sel.health_stats().tiers[EngineChoice::Pow2.index()].state,
            BreakerState::Open
        );
        // ...then heal the backend and run out the cooldown clock
        sel.set_chaos(Arc::new(FaultPlan::quiet(2)));
        for _ in 0..Health::COOLDOWN_DISPATCHES + 2 {
            sel.translate(&ctx, &batch, &mut out).unwrap();
        }
        let tier = sel.health_stats().tiers[EngineChoice::Pow2.index()];
        assert_eq!(tier.state, BreakerState::Closed, "probe must re-close");
        assert!(tier.probes >= 1, "recovery must go through a probe");
        assert_eq!(sel.choice(&layout, 1), EngineChoice::Pow2);
    }

    #[test]
    fn structural_refusals_still_propagate_loudly() {
        // A fallback that masked a LengthMismatch would hide a caller
        // bug: structural errors bypass the ladder.
        let sel = EngineSelector::new();
        let layout = ArrayLayout::new(4, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        batch.push(SharedPtr::NULL, 1);
        batch.incs.push(7); // corrupt the SoA invariant
        let mut out = BatchOut::new();
        let err = sel.translate(&ctx, &batch, &mut out).unwrap_err();
        assert!(matches!(err, EngineError::LengthMismatch { .. }));
        assert_eq!(sel.health_stats().fallback_runs, 0);
    }

    #[test]
    fn gather_leg_buckets_multi_owner_increment_batches() {
        let sel = EngineSelector::new().with_shard_workers(1);
        let layout = ArrayLayout::new(4, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        // 16 independent gathers over 3 owners — well past the default
        // gather threshold
        let mut batch = PtrBatch::new();
        for i in 0..16u64 {
            batch.push(SharedPtr::NULL, (i * 5) % 12);
        }
        let (mut via, mut direct) = (Vec::new(), Vec::new());
        sel.increment(&ctx, &batch, &mut via).unwrap();
        SoftwareEngine.increment(&ctx, &batch, &mut direct).unwrap();
        assert_eq!(via, direct, "planned path must stay bit-identical");
        let g = sel.gather_stats();
        assert_eq!(g.plans, 1);
        assert_eq!(g.bucketed_ptrs, 16);
        assert_eq!(g.fallback, 0);
    }

    #[test]
    fn gather_leg_serves_single_owner_batches_direct() {
        let sel = EngineSelector::new().with_shard_workers(1);
        let layout = ArrayLayout::new(4, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        // 12 increments all landing on thread 0 (phase stays in block
        // 0): inspection finds one owner and the batch goes direct
        let mut batch = PtrBatch::new();
        for i in 0..12u64 {
            batch.push(SharedPtr::NULL, i % 4);
        }
        let mut out = Vec::new();
        sel.increment(&ctx, &batch, &mut out).unwrap();
        let g = sel.gather_stats();
        assert_eq!(g.plans, 0);
        assert_eq!(g.fallback, 1);
        // below the threshold nothing is even inspected
        let mut tiny = PtrBatch::new();
        tiny.push(SharedPtr::NULL, 5);
        tiny.push(SharedPtr::NULL, 9);
        sel.increment(&ctx, &tiny, &mut out).unwrap();
        let g2 = sel.gather_stats();
        assert_eq!((g2.plans, g2.fallback), (0, 1));
    }

    #[test]
    fn gather_threshold_is_tunable_and_calibratable() {
        let off = EngineSelector::new().with_gather_threshold(usize::MAX);
        let layout = ArrayLayout::new(4, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..64u64 {
            batch.push(SharedPtr::NULL, i);
        }
        let mut out = Vec::new();
        off.increment(&ctx, &batch, &mut out).unwrap();
        assert_eq!(off.gather_stats(), GatherStats::default());
        // calibration measures a positive bucketing leg and keeps the
        // threshold at or above the compiled-window floor
        let cal = EngineSelector::new().with_gather_calibration();
        assert!(cal.cost_model().gather_bucket_ns_per_ptr > 0.0);
        assert!(
            cal.gather_threshold() >= EngineSelector::DEFAULT_GATHER_THRESHOLD
        );
    }

    #[test]
    fn gather_leg_is_chaos_transparent() {
        use super::super::fault::FaultSpec;
        // every bucket dispatch draws an injected error; the fallback
        // ladder must absorb all of them and the splice must still be
        // bit-identical
        let sel = EngineSelector::new()
            .with_shard_workers(1)
            .with_chaos(Arc::new(FaultPlan::new(FaultSpec {
                error: 1.0,
                ..FaultSpec::quiet(0xDEAD_BEEF)
            })));
        let layout = ArrayLayout::new(4, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..32u64 {
            batch.push(SharedPtr::NULL, (i * 7) % 48);
        }
        let (mut via, mut direct) = (Vec::new(), Vec::new());
        sel.increment(&ctx, &batch, &mut via).unwrap();
        SoftwareEngine.increment(&ctx, &batch, &mut direct).unwrap();
        assert_eq!(via, direct);
        let h = sel.health_stats();
        assert!(h.injected_faults >= 1);
        assert!(h.fallback_runs >= 1);
        assert_eq!(sel.gather_stats().plans, 1);
    }

    #[test]
    fn simd_leg_prices_vectorized_batches_and_counts_lanes() {
        let sel = EngineSelector::new().with_shard_workers(1);
        // non-pow2 CG geometry: the reciprocal lanes undercut scalar
        // software once the batch clears the serial/vector cutover
        let layout = ArrayLayout::new(3, 112, 5);
        assert_eq!(sel.choice(&layout, 8), EngineChoice::Software);
        assert_eq!(sel.choice(&layout, 64), EngineChoice::Simd);
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 2).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..67u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 11), i % 29);
        }
        let (mut via, mut direct) = (BatchOut::new(), BatchOut::new());
        sel.translate(&ctx, &batch, &mut via).unwrap();
        SoftwareEngine.translate(&ctx, &batch, &mut direct).unwrap();
        assert_eq!(via, direct, "vector lanes must stay bit-identical");
        assert_eq!(sel.hit_counts()[EngineChoice::Simd.index()].1, 1);
        let s = sel.simd_stats();
        assert_eq!(s.batches, 1);
        assert_eq!(s.lane_ptrs, 64);
        assert_eq!(s.tail_ptrs, 3);
    }

    #[test]
    fn forced_cheap_simd_wins_pow2_geometry_but_never_walks() {
        let sel = EngineSelector::new()
            .with_shard_workers(1)
            .with_simd_cost(0.01);
        let pow2 = ArrayLayout::new(4, 8, 4);
        assert_eq!(sel.choice(&pow2, 64), EngineChoice::Simd);
        // walks have no lanes to fill: the O(1) stepper stays scalar
        assert_eq!(sel.choice_walk(&pow2, 64), EngineChoice::Pow2);
        // and the cutover still floors tiny batches
        assert_eq!(sel.choice(&pow2, 4), EngineChoice::Pow2);
    }

    #[test]
    fn simd_calibration_survives_cost_model_order() {
        let sentinel = CostModel {
            simd_ns_per_ptr: 7777.0,
            ..CostModel::default()
        };
        let before = EngineSelector::new()
            .with_cost_model(sentinel)
            .with_simd_cost(0.5);
        let after = EngineSelector::new()
            .with_simd_cost(0.5)
            .with_cost_model(sentinel);
        for (label, sel) in [("cost-first", &before), ("simd-first", &after)] {
            assert_eq!(
                sel.cost_model().simd_ns_per_ptr,
                0.5,
                "{label}: measurement lost"
            );
        }
        // a fresh calibration measures a positive per-pointer cost
        let cal = EngineSelector::new().with_simd_calibration();
        assert!(cal.cost_model().simd_ns_per_ptr > 0.0);
    }

    #[test]
    fn planner_engages_past_threshold_and_stays_bit_identical() {
        let sel = EngineSelector::new()
            .with_shard_workers(1)
            .with_plan_threshold(64)
            .with_plan_tile(16);
        let layout = ArrayLayout::new(3, 112, 5);
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 1).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..200u64 {
            batch.push(
                SharedPtr::for_index(&layout, 0, (i * 37) % 512),
                i % 13,
            );
        }
        let (mut via, mut direct) = (BatchOut::new(), BatchOut::new());
        sel.translate(&ctx, &batch, &mut via).unwrap();
        SoftwareEngine.translate(&ctx, &batch, &mut direct).unwrap();
        assert_eq!(via, direct, "planned path must stay bit-identical");
        let p = sel.plan_stats();
        assert_eq!(p.plans, 1);
        assert_eq!(p.tiles, 13); // ceil(200 / 16)
        assert_eq!(p.planned_ptrs, 200);
        assert_eq!(p.fallback, 0);
        // increments plan too (gather disabled so the leg is reachable)
        let sel2 = EngineSelector::new()
            .with_shard_workers(1)
            .with_gather_threshold(usize::MAX)
            .with_plan_threshold(64)
            .with_plan_tile(16);
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        sel2.increment(&ctx, &batch, &mut pa).unwrap();
        SoftwareEngine.increment(&ctx, &batch, &mut pb).unwrap();
        assert_eq!(pa, pb);
        assert_eq!(sel2.plan_stats().plans, 1);
        // a batch under one tile degenerates: counted as fallback
        let sel3 = EngineSelector::new()
            .with_shard_workers(1)
            .with_plan_threshold(64)
            .with_plan_tile(4096);
        let mut out = BatchOut::new();
        sel3.translate(&ctx, &batch, &mut out).unwrap();
        assert_eq!(out, direct);
        let p3 = sel3.plan_stats();
        assert_eq!((p3.plans, p3.fallback), (0, 1));
    }

    #[test]
    fn simd_faults_degrade_through_the_ladder_bit_identically() {
        use super::super::fault::FaultSpec;
        // the vector tier is the argmin pick here, every dispatch draws
        // an injected error, and the ladder must absorb all of them
        let sel = EngineSelector::new()
            .with_shard_workers(1)
            .with_chaos(Arc::new(FaultPlan::new(FaultSpec {
                error: 1.0,
                ..FaultSpec::quiet(0xFEED)
            })));
        let layout = ArrayLayout::new(3, 112, 5);
        assert_eq!(sel.choice(&layout, 64), EngineChoice::Simd);
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..64u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 3), i);
        }
        let (mut via, mut direct) = (BatchOut::new(), BatchOut::new());
        for _ in 0..8 {
            sel.translate(&ctx, &batch, &mut via).unwrap();
        }
        SoftwareEngine.translate(&ctx, &batch, &mut direct).unwrap();
        assert_eq!(via, direct);
        let h = sel.health_stats();
        let simd = h.tiers[EngineChoice::Simd.index()];
        assert_eq!(simd.state, BreakerState::Open, "simd breaker trips");
        assert!(simd.trips >= 1);
        assert!(simd.failures >= u64::from(Health::TRIP_CONSEC));
        // quarantined: the argmin re-runs over the survivors
        assert_eq!(sel.choice(&layout, 64), EngineChoice::Software);
        // a clean vector serve never reached the counters
        assert_eq!(sel.simd_stats().batches, 0);
    }

    #[test]
    fn auto_engine_matches_both_scalar_backends() {
        let table = BaseTable::regular(8, 1 << 32, 1 << 32);
        for layout in [ArrayLayout::new(4, 8, 8), ArrayLayout::new(3, 112, 5)] {
            let ctx = EngineCtx::new(layout, &table, 1).unwrap();
            let mut batch = PtrBatch::new();
            for i in 0..64 {
                batch.push(SharedPtr::for_index(&layout, 0, i * 3), i);
            }
            let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
            AutoEngine.translate(&ctx, &batch, &mut a).unwrap();
            SoftwareEngine.translate(&ctx, &batch, &mut b).unwrap();
            assert_eq!(a, b, "layout={layout:?}");
        }
    }
}
