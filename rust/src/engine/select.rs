//! Layout-driven backend selection — the runtime mirror of the
//! compiler's `Soft`/`Hw` lowering choice.
//!
//! The policy is the paper's: the shift/mask hardware path whenever the
//! geometry allows it, software Algorithm 1 otherwise.  When the XLA
//! batch unit is compiled in (`--features xla-unit`) and loaded, batches
//! big enough to amortize the PJRT dispatch go to it instead.

use super::{AddressEngine, BatchOut, EngineCtx, EngineError, Pow2Engine, PtrBatch, SoftwareEngine};
use crate::sptr::{ArrayLayout, Locality, SharedPtr};

/// Which backend the selector picked (stable, reportable).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EngineChoice {
    Software,
    Pow2,
    XlaBatch,
}

impl EngineChoice {
    pub fn name(&self) -> &'static str {
        match self {
            EngineChoice::Software => "software",
            EngineChoice::Pow2 => "pow2",
            EngineChoice::XlaBatch => "xla-batch",
        }
    }
}

/// Owns one instance of every available backend and picks the fastest
/// legal one per request.  This is the seam future backends (the Leon3
/// coprocessor model, sharded/remote engines) plug into.
pub struct EngineSelector {
    software: SoftwareEngine,
    pow2: Pow2Engine,
    #[cfg(feature = "xla-unit")]
    xla: Option<super::XlaBatchEngine>,
    /// Minimum batch size worth a PJRT round-trip.
    #[cfg_attr(not(feature = "xla-unit"), allow(dead_code))]
    xla_threshold: usize,
}

impl EngineSelector {
    /// Default minimum batch size routed to the XLA unit (dispatch to
    /// PJRT costs tens of microseconds; small batches stay scalar).
    pub const DEFAULT_XLA_THRESHOLD: usize = 1024;

    pub fn new() -> Self {
        Self {
            software: SoftwareEngine,
            pow2: Pow2Engine,
            #[cfg(feature = "xla-unit")]
            xla: None,
            xla_threshold: Self::DEFAULT_XLA_THRESHOLD,
        }
    }

    /// Install the XLA batch backend (takes priority for large pow2
    /// batches).
    #[cfg(feature = "xla-unit")]
    pub fn with_xla(mut self, engine: super::XlaBatchEngine) -> Self {
        self.xla = Some(engine);
        self
    }

    /// Route batches of at least `n` pointers to the XLA unit.
    #[cfg(feature = "xla-unit")]
    pub fn with_xla_threshold(mut self, n: usize) -> Self {
        self.xla_threshold = n;
        self
    }

    #[cfg(feature = "xla-unit")]
    pub fn has_xla(&self) -> bool {
        self.xla.is_some()
    }

    /// The backend the selector would use for `layout` at `batch_len`.
    pub fn choice(&self, layout: &ArrayLayout, batch_len: usize) -> EngineChoice {
        let _ = batch_len; // consulted only when the xla-unit backend is built in
        if !layout.hw_supported() {
            return EngineChoice::Software;
        }
        #[cfg(feature = "xla-unit")]
        if let Some(x) = &self.xla {
            if batch_len >= self.xla_threshold && x.supports(layout) {
                return EngineChoice::XlaBatch;
            }
        }
        EngineChoice::Pow2
    }

    /// Pick the fastest legal backend for `layout` at `batch_len`.
    pub fn select(&self, layout: &ArrayLayout, batch_len: usize) -> &dyn AddressEngine {
        match self.choice(layout, batch_len) {
            EngineChoice::Software => &self.software,
            EngineChoice::Pow2 => &self.pow2,
            #[cfg(feature = "xla-unit")]
            EngineChoice::XlaBatch => {
                self.xla.as_ref().expect("choice() returned XlaBatch without a unit")
            }
            #[cfg(not(feature = "xla-unit"))]
            EngineChoice::XlaBatch => &self.software,
        }
    }

    // ---- convenience passthroughs (select per call) ----

    pub fn translate(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        self.select(&ctx.layout, batch.len()).translate(ctx, batch, out)
    }

    pub fn increment(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        self.select(&ctx.layout, batch.len()).increment(ctx, batch, out)
    }

    pub fn walk(
        &self,
        ctx: &EngineCtx,
        start: SharedPtr,
        inc: u64,
        steps: usize,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        self.select(&ctx.layout, steps).walk(ctx, start, inc, steps, out)
    }

    pub fn translate_one(
        &self,
        ctx: &EngineCtx,
        ptr: SharedPtr,
        inc: u64,
    ) -> Result<(SharedPtr, u64, Locality), EngineError> {
        self.select(&ctx.layout, 1).translate_one(ctx, ptr, inc)
    }
}

impl Default for EngineSelector {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sptr::BaseTable;

    #[test]
    fn selection_mirrors_the_compiler_variant_choice() {
        let sel = EngineSelector::new();
        // pow2 geometry -> hardware fast path (any batch size)
        assert_eq!(sel.choice(&ArrayLayout::new(4, 4, 4), 1), EngineChoice::Pow2);
        assert_eq!(
            sel.choice(&ArrayLayout::new(64, 8, 16), 1 << 20),
            EngineChoice::Pow2
        );
        // the CG w/w_tmp case (elemsize 56016) -> software fallback
        assert_eq!(
            sel.choice(&ArrayLayout::new(1, 56016, 8), 1 << 20),
            EngineChoice::Software
        );
        assert_eq!(sel.select(&ArrayLayout::new(1, 56016, 8), 4).name(), "software");
        assert_eq!(sel.select(&ArrayLayout::new(4, 4, 4), 4).name(), "pow2");
    }

    #[test]
    fn passthroughs_dispatch_to_the_selected_backend() {
        let sel = EngineSelector::new();
        let layout = ArrayLayout::new(4, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0);
        let mut out = BatchOut::new();
        sel.walk(&ctx, SharedPtr::NULL, 1, 12, &mut out).unwrap();
        assert_eq!(out.len(), 12);
        for (i, p) in out.ptrs.iter().enumerate() {
            assert_eq!(*p, SharedPtr::for_index(&layout, 0, i as u64));
        }
        let (q, sysva, _) = sel.translate_one(&ctx, SharedPtr::NULL, 5).unwrap();
        assert_eq!(q, SharedPtr::for_index(&layout, 0, 5));
        assert_eq!(sysva, table.base(q.thread) + q.va);
    }
}
