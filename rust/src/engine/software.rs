//! The general-path backend: Algorithm 1 with divide/modulo, legal for
//! every distribution geometry — what the Berkeley runtime executes in
//! software and the baseline every other backend must agree with.

use super::{AddressEngine, BatchOut, EngineCtx, EngineError, PtrBatch};
use crate::sptr::{
    increment_general, locality, ArrayLayout, BaseTable, Locality, SharedPtr,
    Topology,
};

/// Software Algorithm 1 (divide/modulo).  Supports all layouts.
#[derive(Clone, Copy, Debug, Default)]
pub struct SoftwareEngine;

impl SoftwareEngine {
    /// One fused mapping — increment, LUT translate, locality classify —
    /// over already-hoisted context fields.  The scalar path
    /// (`translate_one`), the batched loop below, and the simd tier's
    /// scalar tail all route through this one function so they cannot
    /// drift.
    #[inline]
    pub(super) fn map_one(
        layout: &ArrayLayout,
        table: &BaseTable,
        mythread: u32,
        topo: &Topology,
        ptr: &SharedPtr,
        inc: u64,
    ) -> (SharedPtr, u64, Locality) {
        let q = increment_general(ptr, inc, layout);
        let sysva = q.translate(table);
        let loc = locality(q.thread, mythread, topo);
        (q, sysva, loc)
    }
}

impl AddressEngine for SoftwareEngine {
    fn name(&self) -> &'static str {
        "software"
    }

    fn supports(&self, _layout: &ArrayLayout) -> bool {
        true
    }

    fn translate(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        batch.check()?;
        out.clear();
        out.reserve(batch.len());
        // Hoist every context field once per batch: `layout`/`topo` are
        // copied to locals so their fields stay in registers instead of
        // being re-loaded through `&EngineCtx` on every element.
        let layout = ctx.layout;
        let table = ctx.table;
        let mythread = ctx.mythread;
        let topo = ctx.topo;
        for (p, &inc) in batch.ptrs.iter().zip(&batch.incs) {
            let (q, sysva, loc) =
                Self::map_one(&layout, table, mythread, &topo, p, inc);
            out.push(q, sysva, loc);
        }
        Ok(())
    }

    fn increment(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        batch.check()?;
        out.clear();
        out.reserve(batch.len());
        let layout = ctx.layout; // hoisted: one load per batch, not per element
        for (p, &inc) in batch.ptrs.iter().zip(&batch.incs) {
            out.push(increment_general(p, inc, &layout));
        }
        Ok(())
    }

    /// Walks are O(1) per step: the stride is factored through the
    /// layout once ([`crate::sptr::WalkCursor`]) instead of paying the
    /// full divide/modulo Algorithm 1 on every step.
    fn walk(
        &self,
        ctx: &EngineCtx,
        start: SharedPtr,
        inc: u64,
        steps: usize,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        super::cursor_walk(ctx, start, inc, steps, out)
    }

    fn translate_one(
        &self,
        ctx: &EngineCtx,
        ptr: SharedPtr,
        inc: u64,
    ) -> Result<(SharedPtr, u64, Locality), EngineError> {
        Ok(Self::map_one(
            &ctx.layout,
            ctx.table,
            ctx.mythread,
            &ctx.topo,
            &ptr,
            inc,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sptr::BaseTable;

    #[test]
    fn walk_matches_for_index_on_nonpow2_layout() {
        // CG-style non-pow2 geometry: only this backend is legal.
        let layout = ArrayLayout::new(3, 24, 5);
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 2).unwrap();
        let e = SoftwareEngine;
        assert!(e.supports(&layout));
        let mut out = BatchOut::new();
        e.walk(&ctx, SharedPtr::for_index(&layout, 64, 0), 1, 40, &mut out)
            .unwrap();
        for (i, p) in out.ptrs.iter().enumerate() {
            assert_eq!(*p, SharedPtr::for_index(&layout, 64, i as u64));
            assert_eq!(out.sysva[i], table.base(p.thread) + p.va);
        }
    }

    #[test]
    fn translate_one_agrees_with_batched_translate() {
        let layout = ArrayLayout::new(4, 4, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let e = SoftwareEngine;
        let p = SharedPtr::for_index(&layout, 0, 7);
        let mut batch = PtrBatch::new();
        batch.push(p, 9);
        let mut out = BatchOut::new();
        e.translate(&ctx, &batch, &mut out).unwrap();
        let (q, sysva, loc) = e.translate_one(&ctx, p, 9).unwrap();
        assert_eq!((q, sysva, loc), (out.ptrs[0], out.sysva[0], out.loc[0]));
    }
}
