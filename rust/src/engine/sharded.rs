//! The throughput tier: a backend that partitions work across a
//! persistent pool of worker threads, each running a clone of the same
//! inner [`AddressEngine`].
//!
//! A request batch is split into contiguous shards, scattered over the
//! pool, and the shard results are spliced back **in shard order**, so
//! the output is bit-identical to what the inner engine would produce
//! single-threaded — at any shard count.  That shard-count invariance
//! is a conformance property (`rust/tests/engine_conformance.rs` checks
//! 1/2/4/7 shards differentially against the inner engine, including
//! CG's non-pow2 112-byte-element layout).
//!
//! Walks shard over the *step range*: shard `i` starts `lo_i` strides
//! past the walk origin, computed with one `increment_general` — exact
//! by the increment composition law (`inc(a)∘inc(b) = inc(a+b)`) — and
//! then walks its chunk with the inner engine's O(1) stepper.
//!
//! The pool is created once and reused for the engine's lifetime
//! (`std::thread` + mpsc channels); dropping the engine closes the
//! channels and joins the workers.  Batches below
//! `min_shard_len` per shard are served inline by the inner engine —
//! the channel round-trip only pays for itself on large requests,
//! which is also what the selector's cost model encodes.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::plan::{Tile, TilePlan};
use super::{AddressEngine, BatchOut, EngineCtx, EngineError, PtrBatch};
use crate::sptr::{
    increment_general, ArrayLayout, BaseTable, Locality, SharedPtr, Topology,
};

/// Owned snapshot of an [`EngineCtx`] that can cross a channel (the
/// borrowed base table becomes a shared `Arc` clone).
#[derive(Clone)]
struct OwnedCtx {
    layout: ArrayLayout,
    table: Arc<BaseTable>,
    mythread: u32,
    topo: Topology,
}

impl OwnedCtx {
    fn snapshot(ctx: &EngineCtx) -> Self {
        Self {
            layout: ctx.layout,
            table: Arc::new(ctx.table.clone()),
            mythread: ctx.mythread,
            topo: ctx.topo,
        }
    }
}

/// One shard's worth of work.
enum Task {
    /// `translate` (fused) when true, `increment` (pointers only)
    /// otherwise.
    Map {
        ptrs: Vec<SharedPtr>,
        incs: Vec<u64>,
        translate: bool,
    },
    Walk {
        start: SharedPtr,
        inc: u64,
        steps: usize,
    },
}

enum ShardOut {
    Batch(BatchOut),
    Ptrs(Vec<SharedPtr>),
}

struct Job {
    shard: usize,
    ctx: OwnedCtx,
    task: Task,
    reply: Sender<(usize, Result<ShardOut, EngineError>)>,
}

fn run_task<E: AddressEngine>(
    inner: &E,
    ctx: &OwnedCtx,
    task: Task,
) -> Result<ShardOut, EngineError> {
    let ectx = EngineCtx::new(ctx.layout, ctx.table.as_ref(), ctx.mythread)?
        .with_topology(ctx.topo);
    match task {
        Task::Map { ptrs, incs, translate } => {
            let batch = PtrBatch { ptrs, incs };
            if translate {
                let mut out = BatchOut::new();
                inner.translate(&ectx, &batch, &mut out)?;
                Ok(ShardOut::Batch(out))
            } else {
                let mut out = Vec::new();
                inner.increment(&ectx, &batch, &mut out)?;
                Ok(ShardOut::Ptrs(out))
            }
        }
        Task::Walk { start, inc, steps } => {
            let mut out = BatchOut::new();
            inner.walk(&ectx, start, inc, steps, &mut out)?;
            Ok(ShardOut::Batch(out))
        }
    }
}

/// Shard-parallel wrapper around any inner [`AddressEngine`].
pub struct ShardedEngine<E: AddressEngine + Send + Sync + 'static> {
    inner: Arc<E>,
    senders: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    min_shard_len: usize,
}

impl<E: AddressEngine + Send + Sync + 'static> ShardedEngine<E> {
    /// Below this many requests per shard the channel round-trip costs
    /// more than it saves; such batches run inline on the inner engine.
    pub const DEFAULT_MIN_SHARD_LEN: usize = 2048;

    /// Spawn a persistent pool of `shards` workers (clamped to ≥ 1),
    /// each serving requests with a shared handle to `inner`.
    pub fn new(inner: E, shards: usize) -> Self {
        let shards = shards.max(1);
        let inner = Arc::new(inner);
        let mut senders = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for _ in 0..shards {
            let (tx, rx) = channel::<Job>();
            let worker_inner = Arc::clone(&inner);
            handles.push(std::thread::spawn(move || {
                for job in rx.iter() {
                    let Job { shard, ctx, task, reply } = job;
                    let res = run_task(worker_inner.as_ref(), &ctx, task);
                    // A dropped receiver means the caller already gave
                    // up on this request (another shard errored).
                    let _ = reply.send((shard, res));
                }
            }));
            senders.push(tx);
        }
        Self {
            inner,
            senders,
            handles,
            min_shard_len: Self::DEFAULT_MIN_SHARD_LEN,
        }
    }

    /// Override the inline-serve threshold (conformance tests set 1 to
    /// force real fan-out on small batches).
    pub fn with_min_shard_len(mut self, n: usize) -> Self {
        self.min_shard_len = n.max(1);
        self
    }

    /// Worker-pool size.
    pub fn shards(&self) -> usize {
        self.senders.len()
    }

    /// The wrapped engine.
    pub fn inner(&self) -> &E {
        self.inner.as_ref()
    }

    /// How many shards a request of `n` items fans out to.
    fn fanout(&self, n: usize) -> usize {
        (n / self.min_shard_len).clamp(1, self.senders.len())
    }

    /// Gather `k` shard replies back into shard order.
    fn collect(
        rx: Receiver<(usize, Result<ShardOut, EngineError>)>,
        k: usize,
    ) -> Result<Vec<ShardOut>, EngineError> {
        let mut parts: Vec<Option<ShardOut>> = (0..k).map(|_| None).collect();
        for _ in 0..k {
            let (i, res) = rx.recv().map_err(|_| {
                EngineError::Backend("sharded: worker pool shut down".into())
            })?;
            parts[i] = Some(res?);
        }
        Ok(parts
            .into_iter()
            .map(|p| p.expect("every shard replied exactly once"))
            .collect())
    }

    /// Splice batch-shaped shard results in shard order, erroring hard
    /// on a mismatched variant or a short/overlong splice — a worker
    /// bug must surface as [`EngineError::Backend`], never as silently
    /// truncated output.
    fn splice_batches(
        parts: Vec<ShardOut>,
        out: &mut BatchOut,
        want_len: usize,
    ) -> Result<(), EngineError> {
        out.clear();
        out.reserve(want_len);
        for part in parts {
            match part {
                ShardOut::Batch(mut b) => out.append(&mut b),
                ShardOut::Ptrs(_) => {
                    return Err(EngineError::Backend(
                        "sharded: worker answered a translate/walk shard \
                         with increment-shaped output"
                            .into(),
                    ))
                }
            }
        }
        if out.len() != want_len {
            return Err(EngineError::Backend(format!(
                "sharded: spliced {} results for a {want_len}-item request",
                out.len()
            )));
        }
        Ok(())
    }

    /// [`splice_batches`](Self::splice_batches) for increment-shaped
    /// shards.
    fn splice_ptrs(
        parts: Vec<ShardOut>,
        out: &mut Vec<SharedPtr>,
        want_len: usize,
    ) -> Result<(), EngineError> {
        out.clear();
        out.reserve(want_len);
        for part in parts {
            match part {
                ShardOut::Ptrs(mut v) => out.append(&mut v),
                ShardOut::Batch(_) => {
                    return Err(EngineError::Backend(
                        "sharded: worker answered an increment shard with \
                         translate-shaped output"
                            .into(),
                    ))
                }
            }
        }
        if out.len() != want_len {
            return Err(EngineError::Backend(format!(
                "sharded: spliced {} results for a {want_len}-item request",
                out.len()
            )));
        }
        Ok(())
    }

    /// Scatter a map-style batch over `k` shards and gather in order.
    fn map_sharded(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        k: usize,
        translate: bool,
    ) -> Result<Vec<ShardOut>, EngineError> {
        let owned = OwnedCtx::snapshot(ctx);
        let (reply_tx, reply_rx) = channel();
        let chunk = batch.len().div_ceil(k);
        for i in 0..k {
            // Both bounds clamp: ceil-sized chunks can exhaust the
            // batch before the last shard (e.g. 5 items over 4 shards),
            // leaving trailing shards a legal empty range.
            let lo = (i * chunk).min(batch.len());
            let hi = ((i + 1) * chunk).min(batch.len());
            let job = Job {
                shard: i,
                ctx: owned.clone(),
                task: Task::Map {
                    ptrs: batch.ptrs[lo..hi].to_vec(),
                    incs: batch.incs[lo..hi].to_vec(),
                    translate,
                },
                reply: reply_tx.clone(),
            };
            self.senders[i].send(job).map_err(|_| {
                EngineError::Backend("sharded: worker pool shut down".into())
            })?;
        }
        drop(reply_tx);
        Self::collect(reply_rx, k)
    }

    /// Scatter a planned batch over the pool by **whole tiles**: each
    /// worker gets one contiguous run of the plan's affinity-sorted
    /// tile list ([`TilePlan::groups`]) gathered into a single
    /// owner-coherent frame, instead of a raw index range of the
    /// original batch.  Returns the per-group shard outputs in group
    /// order; callers scatter them back through the tiles' original
    /// ranges.
    fn map_planned<'p>(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        plan: &'p TilePlan,
        k: usize,
        translate: bool,
    ) -> Result<(Vec<&'p [Tile]>, Vec<ShardOut>), EngineError> {
        let groups = plan.groups(k);
        let owned = OwnedCtx::snapshot(ctx);
        let (reply_tx, reply_rx) = channel();
        for (i, group) in groups.iter().enumerate() {
            let m: usize = group.iter().map(Tile::len).sum();
            let mut ptrs = Vec::with_capacity(m);
            let mut incs = Vec::with_capacity(m);
            for t in *group {
                ptrs.extend_from_slice(&batch.ptrs[t.lo..t.hi]);
                incs.extend_from_slice(&batch.incs[t.lo..t.hi]);
            }
            let job = Job {
                shard: i,
                ctx: owned.clone(),
                task: Task::Map { ptrs, incs, translate },
                reply: reply_tx.clone(),
            };
            self.senders[i].send(job).map_err(|_| {
                EngineError::Backend("sharded: worker pool shut down".into())
            })?;
        }
        drop(reply_tx);
        let parts = Self::collect(reply_rx, groups.len())?;
        Ok((groups, parts))
    }

    /// A plan built for a different batch must be refused before any
    /// shard work is dispatched.
    fn check_plan(
        batch: &PtrBatch,
        plan: &TilePlan,
    ) -> Result<(), EngineError> {
        batch.check()?;
        if batch.len() != plan.len() {
            return Err(EngineError::Backend(format!(
                "plan covers {} requests but batch has {}",
                plan.len(),
                batch.len()
            )));
        }
        Ok(())
    }
}

impl<E: AddressEngine + Send + Sync + 'static> AddressEngine
    for ShardedEngine<E>
{
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn supports(&self, layout: &ArrayLayout) -> bool {
        self.inner.supports(layout)
    }

    fn translate(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        batch.check()?;
        let k = self.fanout(batch.len());
        if k == 1 {
            return self.inner.translate(ctx, batch, out);
        }
        let parts = self.map_sharded(ctx, batch, k, true)?;
        Self::splice_batches(parts, out, batch.len())
    }

    fn increment(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        batch.check()?;
        let k = self.fanout(batch.len());
        if k == 1 {
            return self.inner.increment(ctx, batch, out);
        }
        let parts = self.map_sharded(ctx, batch, k, false)?;
        Self::splice_ptrs(parts, out, batch.len())
    }

    fn walk(
        &self,
        ctx: &EngineCtx,
        start: SharedPtr,
        inc: u64,
        steps: usize,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        let k = self.fanout(steps);
        // One overflow test decides the inline fallback before any job
        // is dispatched (shard offsets never exceed inc*steps).
        if k == 1 || inc.checked_mul(steps as u64).is_none() {
            return self.inner.walk(ctx, start, inc, steps, out);
        }
        let chunk = steps.div_ceil(k);
        let owned = OwnedCtx::snapshot(ctx);
        let (reply_tx, reply_rx) = channel();
        for i in 0..k {
            // Clamp both bounds (see map_sharded): a trailing shard may
            // get an empty step range, which walks to an empty output.
            let lo = (i * chunk).min(steps);
            let hi = ((i + 1) * chunk).min(steps);
            // Shard i's origin is `lo` strides past `start`; one
            // general increment by lo*inc lands on the identical
            // pointer by the composition law.
            let shard_start =
                increment_general(&start, inc * lo as u64, &ctx.layout);
            let job = Job {
                shard: i,
                ctx: owned.clone(),
                task: Task::Walk { start: shard_start, inc, steps: hi - lo },
                reply: reply_tx.clone(),
            };
            self.senders[i].send(job).map_err(|_| {
                EngineError::Backend("sharded: worker pool shut down".into())
            })?;
        }
        drop(reply_tx);
        let parts = Self::collect(reply_rx, k)?;
        Self::splice_batches(parts, out, steps)
    }

    /// The planner-aware override: shard over planned tiles instead of
    /// raw index ranges.  Each worker serves one contiguous run of
    /// affinity-sorted tiles; results scatter back through every tile's
    /// original range, so output is bit-identical to the unplanned path
    /// at any tile size and shard count.
    fn translate_planned(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        plan: &TilePlan,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        Self::check_plan(batch, plan)?;
        let k = self.fanout(batch.len());
        if k == 1 {
            // below the pool's economy threshold: sequential
            // cache-blocked execution on the inner engine
            return plan.execute_translate(batch, out, &mut |sub, sink| {
                self.inner.translate(ctx, sub, sink)
            });
        }
        let (groups, parts) = self.map_planned(ctx, batch, plan, k, true)?;
        out.clear();
        out.ptrs.resize(batch.len(), SharedPtr::NULL);
        out.sysva.resize(batch.len(), 0);
        out.loc.resize(batch.len(), Locality::Local);
        for (group, part) in groups.iter().zip(parts) {
            let b = match part {
                ShardOut::Batch(b) => b,
                ShardOut::Ptrs(_) => {
                    return Err(EngineError::Backend(
                        "sharded: worker answered a planned translate with \
                         increment-shaped output"
                            .into(),
                    ))
                }
            };
            let want: usize = group.iter().map(Tile::len).sum();
            if b.len() != want {
                return Err(EngineError::Backend(format!(
                    "sharded: planned group returned {} results for {want} \
                     requests",
                    b.len()
                )));
            }
            let mut off = 0usize;
            for t in *group {
                out.ptrs[t.lo..t.hi]
                    .copy_from_slice(&b.ptrs[off..off + t.len()]);
                out.sysva[t.lo..t.hi]
                    .copy_from_slice(&b.sysva[off..off + t.len()]);
                out.loc[t.lo..t.hi].copy_from_slice(&b.loc[off..off + t.len()]);
                off += t.len();
            }
        }
        Ok(())
    }

    /// Increment-only form of the planned override.
    fn increment_planned(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        plan: &TilePlan,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        Self::check_plan(batch, plan)?;
        let k = self.fanout(batch.len());
        if k == 1 {
            return plan.execute_increment(batch, out, &mut |sub, sink| {
                self.inner.increment(ctx, sub, sink)
            });
        }
        let (groups, parts) = self.map_planned(ctx, batch, plan, k, false)?;
        out.clear();
        out.resize(batch.len(), SharedPtr::NULL);
        for (group, part) in groups.iter().zip(parts) {
            let v = match part {
                ShardOut::Ptrs(v) => v,
                ShardOut::Batch(_) => {
                    return Err(EngineError::Backend(
                        "sharded: worker answered a planned increment with \
                         translate-shaped output"
                            .into(),
                    ))
                }
            };
            let want: usize = group.iter().map(Tile::len).sum();
            if v.len() != want {
                return Err(EngineError::Backend(format!(
                    "sharded: planned group returned {} results for {want} \
                     requests",
                    v.len()
                )));
            }
            let mut off = 0usize;
            for t in *group {
                out[t.lo..t.hi].copy_from_slice(&v[off..off + t.len()]);
                off += t.len();
            }
        }
        Ok(())
    }

    fn translate_one(
        &self,
        ctx: &EngineCtx,
        ptr: SharedPtr,
        inc: u64,
    ) -> Result<(SharedPtr, u64, Locality), EngineError> {
        // Scalar requests are never worth a channel round-trip.
        self.inner.translate_one(ctx, ptr, inc)
    }
}

impl<E: AddressEngine + Send + Sync + 'static> Drop for ShardedEngine<E> {
    fn drop(&mut self) {
        // Closing the job channels ends every worker's `rx.iter()`.
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Pow2Engine, SoftwareEngine};
    use super::*;

    #[test]
    fn pool_is_reused_across_requests_and_matches_inner() {
        let sharded = ShardedEngine::new(SoftwareEngine, 3).with_min_shard_len(1);
        let layout = ArrayLayout::new(3, 24, 5);
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 2).unwrap();
        for round in 0..4u64 {
            let mut batch = PtrBatch::new();
            for i in 0..97 {
                batch.push(
                    SharedPtr::for_index(&layout, 0, i * 5 + round),
                    i + round,
                );
            }
            let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
            sharded.translate(&ctx, &batch, &mut a).unwrap();
            SoftwareEngine.translate(&ctx, &batch, &mut b).unwrap();
            assert_eq!(a, b, "round {round}");
        }
    }

    #[test]
    fn small_batches_run_inline() {
        let sharded = ShardedEngine::new(SoftwareEngine, 4);
        assert_eq!(sharded.fanout(1), 1);
        assert_eq!(sharded.fanout(ShardedEngine::<SoftwareEngine>::DEFAULT_MIN_SHARD_LEN - 1), 1);
        assert_eq!(sharded.fanout(usize::MAX), 4);
        let layout = ArrayLayout::new(4, 4, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let (q, sysva, _) =
            sharded.translate_one(&ctx, SharedPtr::NULL, 9).unwrap();
        assert_eq!(q, SharedPtr::for_index(&layout, 0, 9));
        assert_eq!(sysva, table.base(q.thread) + q.va);
    }

    #[test]
    fn inner_errors_propagate_through_the_pool() {
        let sharded = ShardedEngine::new(Pow2Engine, 2).with_min_shard_len(1);
        let layout = ArrayLayout::new(3, 8, 4); // non-pow2: inner refuses
        assert!(!sharded.supports(&layout));
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..8 {
            batch.push(SharedPtr::for_index(&layout, 0, i), 1);
        }
        let mut out = BatchOut::new();
        let err = sharded.translate(&ctx, &batch, &mut out).unwrap_err();
        assert!(matches!(
            err,
            EngineError::UnsupportedLayout { engine: "pow2", .. }
        ));
    }

    #[test]
    fn ragged_tails_clamp_to_empty_shards() {
        // 5 items over 4 ceil-sized chunks exhaust the batch at shard
        // 2; shard 3's range must clamp to empty, not slice [6..5].
        let sharded = ShardedEngine::new(SoftwareEngine, 4).with_min_shard_len(1);
        let layout = ArrayLayout::new(3, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        for n in [1usize, 5, 8, 9, 11] {
            let mut batch = PtrBatch::new();
            for i in 0..n as u64 {
                batch.push(SharedPtr::for_index(&layout, 0, i), 2);
            }
            let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
            sharded.translate(&ctx, &batch, &mut a).unwrap();
            SoftwareEngine.translate(&ctx, &batch, &mut b).unwrap();
            assert_eq!(a, b, "translate n={n}");
            sharded.walk(&ctx, SharedPtr::NULL, 3, n, &mut a).unwrap();
            SoftwareEngine.walk(&ctx, SharedPtr::NULL, 3, n, &mut b).unwrap();
            assert_eq!(a, b, "walk n={n}");
        }
    }

    /// An inner engine that silently drops the last result of every
    /// translate — the worker-bug shape the splice length check exists
    /// to catch.
    #[derive(Clone, Copy)]
    struct TruncatingEngine;

    impl AddressEngine for TruncatingEngine {
        fn name(&self) -> &'static str {
            "truncating"
        }
        fn supports(&self, _layout: &ArrayLayout) -> bool {
            true
        }
        fn translate(
            &self,
            ctx: &EngineCtx,
            batch: &PtrBatch,
            out: &mut BatchOut,
        ) -> Result<(), EngineError> {
            super::super::SoftwareEngine.translate(ctx, batch, out)?;
            out.ptrs.pop();
            out.sysva.pop();
            out.loc.pop();
            Ok(())
        }
        fn increment(
            &self,
            ctx: &EngineCtx,
            batch: &PtrBatch,
            out: &mut Vec<SharedPtr>,
        ) -> Result<(), EngineError> {
            super::super::SoftwareEngine.increment(ctx, batch, out)?;
            out.pop();
            Ok(())
        }
        fn walk(
            &self,
            ctx: &EngineCtx,
            start: SharedPtr,
            inc: u64,
            steps: usize,
            out: &mut BatchOut,
        ) -> Result<(), EngineError> {
            super::super::SoftwareEngine.walk(ctx, start, inc, steps, out)
        }
    }

    #[test]
    fn short_shard_output_is_a_hard_error_not_truncation() {
        let sharded = ShardedEngine::new(TruncatingEngine, 2).with_min_shard_len(1);
        let layout = ArrayLayout::new(4, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..16 {
            batch.push(SharedPtr::for_index(&layout, 0, i), 1);
        }
        let mut out = BatchOut::new();
        let err = sharded.translate(&ctx, &batch, &mut out).unwrap_err();
        assert!(
            matches!(&err, EngineError::Backend(m) if m.contains("spliced")),
            "want a loud splice-length error, got {err:?}"
        );
        let mut ptrs = Vec::new();
        let err = sharded.increment(&ctx, &batch, &mut ptrs).unwrap_err();
        assert!(matches!(&err, EngineError::Backend(m) if m.contains("spliced")));
    }

    #[test]
    fn pool_survives_a_dropped_receiver_and_serves_the_next_request() {
        // When one shard errors, `collect` returns early and drops the
        // reply receiver while other workers may still be sending; the
        // workers swallow that send failure (the caller already gave up
        // on the request) and the pool must stay serviceable.
        let sharded = ShardedEngine::new(Pow2Engine, 2).with_min_shard_len(1);
        let table = BaseTable::regular(8, 1 << 32, 1 << 32);
        let bad = ArrayLayout::new(3, 8, 4); // non-pow2: every shard errors
        let ctx = EngineCtx::new(bad, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..32 {
            batch.push(SharedPtr::for_index(&bad, 0, i), 1);
        }
        let mut out = BatchOut::new();
        for _ in 0..3 {
            assert!(sharded.translate(&ctx, &batch, &mut out).is_err());
        }
        // the pool recovers: a legal request on the same engine works
        let good = ArrayLayout::new(8, 8, 8);
        let ctx = EngineCtx::new(good, &table, 1).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..64 {
            batch.push(SharedPtr::for_index(&good, 0, i * 3), i % 9);
        }
        let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
        sharded.translate(&ctx, &batch, &mut a).unwrap();
        Pow2Engine.translate(&ctx, &batch, &mut b).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn planned_sharding_matches_unplanned_at_any_tile_size() {
        // shard-over-tiles must stay bit-identical to both the inner
        // engine and the unplanned sharded path, for every tile grain
        let sharded =
            ShardedEngine::new(SoftwareEngine, 3).with_min_shard_len(1);
        let layout = ArrayLayout::new(3, 112, 5); // CG non-pow2 geometry
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 1).unwrap();
        let mut batch = PtrBatch::new();
        for i in 0..1234u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 7 % 4096), i % 97);
        }
        let mut want = BatchOut::new();
        SoftwareEngine.translate(&ctx, &batch, &mut want).unwrap();
        let mut want_inc = Vec::new();
        SoftwareEngine.increment(&ctx, &batch, &mut want_inc).unwrap();
        for tile in [1usize, 4, 64, 4096] {
            let plan = TilePlan::from_batch(&ctx, &batch, tile).unwrap();
            let mut got = BatchOut::new();
            sharded
                .translate_planned(&ctx, &batch, &plan, &mut got)
                .unwrap();
            assert_eq!(got, want, "translate tile={tile}");
            let mut got_inc = Vec::new();
            sharded
                .increment_planned(&ctx, &batch, &plan, &mut got_inc)
                .unwrap();
            assert_eq!(got_inc, want_inc, "increment tile={tile}");
        }
        // a plan for a different batch is refused before dispatch
        let plan = TilePlan::from_batch(&ctx, &batch, 64).unwrap();
        let mut short = PtrBatch::new();
        short.push(SharedPtr::NULL, 1);
        let mut out = BatchOut::new();
        assert!(sharded
            .translate_planned(&ctx, &short, &plan, &mut out)
            .is_err());
    }

    #[test]
    fn sharded_walk_matches_inner_walk() {
        let sharded = ShardedEngine::new(Pow2Engine, 4).with_min_shard_len(1);
        let layout = ArrayLayout::new(8, 4, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 1).unwrap();
        let start = SharedPtr::for_index(&layout, 0, 11);
        let (mut a, mut b) = (BatchOut::new(), BatchOut::new());
        sharded.walk(&ctx, start, 5, 333, &mut a).unwrap();
        Pow2Engine.walk(&ctx, start, 5, 333, &mut b).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 333);
        assert_eq!(a.ptrs[0], start);
    }
}
