//! The batched-unit backend: wraps the PJRT/XLA address-mapping unit
//! (the AOT-compiled Pallas kernel) behind the [`AddressEngine`] trait.
//!
//! The artifacts are monomorphic: every executable was lowered with a
//! fixed `UNIT_BATCH` request shape and a fixed `WALK_LEN` trace length.
//! This adapter chunks arbitrary batch and walk sizes through those
//! fixed shapes, so callers never see the artifact geometry.
//!
//! Constraints inherited from the artifacts (all reported as errors,
//! never silently wrong): pow2 layouts only, at most
//! [`MAX_THREADS`](crate::runtime::MAX_THREADS) threads, increments
//! within the i32 lane width.

use super::{AddressEngine, BatchOut, EngineCtx, EngineError, PtrBatch};
use crate::runtime::{UnitCfg, XlaUnit, MAX_THREADS, UNIT_BATCH, WALK_LEN};
use crate::sptr::{increment_pow2, ArrayLayout, Locality, SharedPtr};

/// The XLA batch unit as an `AddressEngine` backend.
pub struct XlaBatchEngine {
    unit: XlaUnit,
}

impl XlaBatchEngine {
    pub fn new(unit: XlaUnit) -> Self {
        Self { unit }
    }

    /// Load the PJRT artifacts from `dir` (see `make artifacts`).
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self, EngineError> {
        XlaUnit::load(dir)
            .map(Self::new)
            .map_err(|e| EngineError::Backend(format!("{e:#}")))
    }

    /// PJRT platform the unit executes on.
    pub fn platform(&self) -> String {
        self.unit.platform()
    }

    /// Artifact hardware-config registers for `ctx`, plus the log2
    /// immediates for the scalar continuation path.
    fn cfg(&self, ctx: &EngineCtx) -> Result<(UnitCfg, (u32, u32, u32)), EngineError> {
        let unsupported = EngineError::UnsupportedLayout {
            engine: self.name(),
            layout: ctx.layout,
        };
        let Some((l2bs, l2es, l2nt)) = ctx.log2s() else {
            return Err(unsupported);
        };
        if ctx.layout.numthreads as usize > MAX_THREADS {
            return Err(unsupported);
        }
        let cfg = UnitCfg {
            log2_blocksize: l2bs,
            log2_elemsize: l2es,
            log2_numthreads: l2nt,
            mythread: ctx.mythread,
            log2_threads_per_mc: ctx.topo.log2_threads_per_mc,
            log2_threads_per_node: ctx.topo.log2_threads_per_node,
        };
        Ok((cfg, (l2bs, l2es, l2nt)))
    }

    /// The artifact carries increments in an i32 lane.
    fn lane_inc(inc: u64) -> Result<u32, EngineError> {
        if inc <= i32::MAX as u64 {
            Ok(inc as u32)
        } else {
            Err(EngineError::Backend(format!(
                "increment {inc} exceeds the artifact's i32 lane"
            )))
        }
    }

    fn lane_incs(incs: &[u64]) -> Result<Vec<u32>, EngineError> {
        incs.iter().map(|&i| Self::lane_inc(i)).collect()
    }

    fn lane_loc(code: i32) -> Result<Locality, EngineError> {
        u8::try_from(code)
            .ok()
            .and_then(Locality::from_code)
            .ok_or_else(|| {
                EngineError::Backend(format!("unit returned locality code {code}"))
            })
    }
}

impl AddressEngine for XlaBatchEngine {
    fn name(&self) -> &'static str {
        "xla-batch"
    }

    fn supports(&self, layout: &ArrayLayout) -> bool {
        layout.hw_supported() && layout.numthreads as usize <= MAX_THREADS
    }

    fn translate(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        let (cfg, _) = self.cfg(ctx)?;
        batch.check()?;
        let incs = Self::lane_incs(&batch.incs)?;
        out.clear();
        out.reserve(batch.len());
        for (ptrs, incs) in batch.ptrs.chunks(UNIT_BATCH).zip(incs.chunks(UNIT_BATCH)) {
            let res = self
                .unit
                .unit_batch(&cfg, ctx.table, ptrs, incs)
                .map_err(|e| EngineError::Backend(format!("{e:#}")))?;
            for i in 0..ptrs.len() {
                let q = SharedPtr {
                    thread: res.thread[i] as u32,
                    phase: res.phase[i] as u64,
                    va: res.va[i] as u64,
                };
                out.push(q, res.sysva[i] as u64, Self::lane_loc(res.loc[i])?);
            }
        }
        Ok(())
    }

    fn increment(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        let (cfg, _) = self.cfg(ctx)?;
        batch.check()?;
        let incs = Self::lane_incs(&batch.incs)?;
        out.clear();
        out.reserve(batch.len());
        for (ptrs, incs) in batch.ptrs.chunks(UNIT_BATCH).zip(incs.chunks(UNIT_BATCH)) {
            let res = self
                .unit
                .inc_batch(&cfg, ptrs, incs)
                .map_err(|e| EngineError::Backend(format!("{e:#}")))?;
            out.extend_from_slice(&res);
        }
        Ok(())
    }

    fn walk(
        &self,
        ctx: &EngineCtx,
        start: SharedPtr,
        inc: u64,
        steps: usize,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        let (cfg, (l2bs, l2es, l2nt)) = self.cfg(ctx)?;
        let inc32 = Self::lane_inc(inc)?;
        out.clear();
        out.reserve(steps);
        // The walker artifact always traces WALK_LEN steps; longer walks
        // chunk through it, shorter ones truncate.  sysva/thread/loc come
        // from the artifact; phase/va are reconstructed with the scalar
        // pow2 pipeline (the walker does not emit them).
        let mut p = start;
        let mut remaining = steps;
        while remaining > 0 {
            let n = remaining.min(WALK_LEN);
            let (sysva, thread, loc) = self
                .unit
                .walk(&cfg, ctx.table, &p, inc32)
                .map_err(|e| EngineError::Backend(format!("{e:#}")))?;
            for i in 0..n {
                debug_assert_eq!(thread[i] as u32, p.thread, "walker step {i}");
                out.push(p, sysva[i] as u64, Self::lane_loc(loc[i])?);
                p = increment_pow2(&p, inc, l2bs, l2es, l2nt);
            }
            remaining -= n;
        }
        Ok(())
    }
}
