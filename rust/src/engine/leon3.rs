//! The FPGA-coprocessor backend: address mapping served by the
//! `leon3::` functional core, one lowered instruction sequence per
//! request.
//!
//! Where [`Pow2Engine`](super::Pow2Engine) calls the shift/mask
//! arithmetic directly, [`Leon3Engine`] goes the long way round on
//! purpose: each [`PtrBatch`] request is lowered to the same
//! `ldi`/`pgas_incr` sequence the prototype compiler emits for the
//! Table-3 SPARC coprocessor (`cpinc_r`), executed instruction by
//! instruction on the shared functional executor
//! ([`cpu::exec::step`](crate::cpu::exec::step)), and billed against
//! the [`Leon3Lat`] cost model at the board's 75 MHz.  The translation
//! of each mapped pointer runs the address-generation stage of a
//! `pgas_ldq` (base-LUT lookup + add against the machine's
//! `base_table`) without the data access, and the locality code comes
//! back through the coprocessor condition register (`cc_loc`), exactly
//! as `cb` (branch-on-locality) would read it.
//!
//! That makes this backend the differential bridge between the two
//! halves of the repo: the host-side engines and the simulated
//! datapath must agree bit-for-bit on every layout the hardware
//! supports (`rust/tests/engine_conformance.rs` and
//! `rust/tests/leon3_engine.rs` enforce it), and every request returns
//! a deterministic **cycle estimate** (readable via
//! [`last_cycles`](Leon3Engine::last_cycles)) so the
//! [`EngineSelector`](super::EngineSelector)'s cost model can price
//! the hardware path from measured numbers instead of guesses.
//!
//! Like the hardware it models, the backend refuses any layout whose
//! blocksize / elemsize / thread count is not a power of two — the
//! same gate as `Pow2Engine`, mirroring the compiler's software
//! fallback — plus the packed-pointer field widths (a pointer must fit
//! the Figure-2 64-bit packing to exist in a coprocessor register).

use std::sync::atomic::{AtomicU64, Ordering};

use super::{AddressEngine, BatchOut, EngineCtx, EngineError, PtrBatch};
use crate::cpu::exec::{step, ArchState};
use crate::isa::{Inst, Reg};
use crate::leon3::{Leon3Lat, FREQ_MHZ};
use crate::mem::MemSystem;
use crate::sptr::{
    pack, unpack, ArrayLayout, BaseTable, Locality, SharedPtr, PHASE_BITS,
    THREAD_BITS, VA_BITS,
};

/// Coprocessor register holding the input pointer.
const R_PTR: Reg = 1;
/// Register holding the element increment.
const R_INC: Reg = 2;
/// Register receiving the incremented pointer.
const R_OUT: Reg = 3;

/// Address mapping on the simulated Leon3 PGAS coprocessor.
///
/// Every request is replayed as real `ldi` + `pgas_incr` instructions
/// on the functional core and billed in Leon3 cycles; outputs are
/// bit-identical to [`SoftwareEngine`](super::SoftwareEngine) on every
/// supported (all-power-of-two) layout.
///
/// # Examples
///
/// ```
/// use pgas_hw::engine::{
///     AddressEngine, BatchOut, EngineCtx, Leon3Engine, PtrBatch,
///     SoftwareEngine,
/// };
/// use pgas_hw::sptr::{ArrayLayout, BaseTable, SharedPtr};
///
/// // shared [4] int A[...] over 4 threads (the paper's Figure 2)
/// let layout = ArrayLayout::new(4, 4, 4);
/// let table = BaseTable::regular(4, 1 << 32, 1 << 32);
/// let ctx = EngineCtx::new(layout, &table, 0).unwrap();
/// let engine = Leon3Engine::new();
/// let mut batch = PtrBatch::new();
/// batch.push(SharedPtr::NULL, 9); // &A[0] + 9 -> A[9]
/// let (mut hw, mut sw) = (BatchOut::new(), BatchOut::new());
/// engine.translate(&ctx, &batch, &mut hw).unwrap();
/// SoftwareEngine.translate(&ctx, &batch, &mut sw).unwrap();
/// assert_eq!(hw, sw); // bit-identical to the software reference
/// assert!(engine.last_cycles() > 0); // and billed in 75 MHz cycles
/// ```
#[derive(Debug, Default)]
pub struct Leon3Engine {
    lat: Leon3Lat,
    /// Cycles billed by the most recent request.
    last_cycles: AtomicU64,
    /// Cycles billed since construction.
    total_cycles: AtomicU64,
}

impl Leon3Engine {
    /// A coprocessor model with the paper's Table-2 latencies.
    pub fn new() -> Self {
        Self::default()
    }

    /// Replace the latency model (e.g. to sweep coprocessor depths).
    pub fn with_lat(mut self, lat: Leon3Lat) -> Self {
        self.lat = lat;
        self
    }

    /// Cycles the most recent `translate`/`increment`/`walk` request
    /// cost on the simulated core (deterministic per request shape).
    pub fn last_cycles(&self) -> u64 {
        self.last_cycles.load(Ordering::Relaxed)
    }

    /// Total cycles billed since construction.
    pub fn total_cycles(&self) -> u64 {
        self.total_cycles.load(Ordering::Relaxed)
    }

    /// The most recent request's simulated runtime in nanoseconds at
    /// the board's 75 MHz.
    pub fn last_runtime_ns(&self) -> f64 {
        self.last_cycles() as f64 * 1e3 / FREQ_MHZ
    }

    /// Measure the *host-side* cost of this backend — wall-clock
    /// `(ns_per_ptr, dispatch_ns)` for translate batches on the
    /// Figure-2 layout — so [`EngineSelector::with_leon3`] can install
    /// measured [`CostModel`] coefficients instead of guessed ones.
    /// The per-pointer slope comes from one large batch; the fixed
    /// per-batch fee (core + LUT setup) from a burst of single-request
    /// batches with the slope subtracted.  (Replaying instructions
    /// through the functional core is orders of magnitude slower than
    /// calling the shift/mask arithmetic directly, and the selector
    /// must know that.)
    ///
    /// [`EngineSelector::with_leon3`]: super::EngineSelector::with_leon3
    /// [`CostModel`]: super::CostModel
    pub fn calibrate(&self) -> (f64, f64) {
        const N: usize = 2048;
        const SMALL_BATCHES: u32 = 64;
        let layout = ArrayLayout::new(4, 4, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0)
            .expect("calibration context is statically valid");
        let mut batch = PtrBatch::with_capacity(N);
        for i in 0..N as u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i % 64), i % 16);
        }
        let mut out = BatchOut::new();
        // calibration is measurement, not service: restore the billing
        // counters afterwards so they keep meaning "cycles of served
        // requests"
        let (last, total) = (self.last_cycles(), self.total_cycles());
        // two warmup passes, then one measured pass for the slope
        for _ in 0..2 {
            self.translate(&ctx, &batch, &mut out)
                .expect("calibration batch is supported");
        }
        let t0 = std::time::Instant::now();
        self.translate(&ctx, &batch, &mut out)
            .expect("calibration batch is supported");
        let ns_per_ptr =
            (t0.elapsed().as_nanos() as f64 / N as f64).max(1.0);
        // the fixed fee: single-request batches minus one pointer's work
        let mut one = PtrBatch::with_capacity(1);
        one.push(SharedPtr::NULL, 1);
        self.translate(&ctx, &one, &mut out)
            .expect("calibration batch is supported");
        let t0 = std::time::Instant::now();
        for _ in 0..SMALL_BATCHES {
            self.translate(&ctx, &one, &mut out)
                .expect("calibration batch is supported");
        }
        let per_batch =
            t0.elapsed().as_nanos() as f64 / SMALL_BATCHES as f64;
        let dispatch_ns = (per_batch - ns_per_ptr).max(0.0);
        self.last_cycles.store(last, Ordering::Relaxed);
        self.total_cycles.store(total, Ordering::Relaxed);
        (ns_per_ptr, dispatch_ns)
    }

    /// The hardware gate: all-pow2 geometry (the shift/mask pipeline)
    /// *and* the Figure-2 packing bounds, or `UnsupportedLayout`.
    fn gate(&self, ctx: &EngineCtx) -> Result<(u8, u8), EngineError> {
        if !self.supports(&ctx.layout) {
            return Err(EngineError::UnsupportedLayout {
                engine: "leon3",
                layout: ctx.layout,
            });
        }
        let (l2bs, l2es, _l2nt) =
            ctx.log2s().expect("supports() guarantees pow2 geometry");
        Ok((l2bs as u8, l2es as u8))
    }

    /// A pointer exists in a coprocessor register only if it fits the
    /// Figure-2 packed fields; refuse (rather than silently truncate
    /// in release builds, where `pack`'s debug_asserts are compiled
    /// out) any input that does not.  Post-increment overflow of the
    /// 38-bit va field remains debug-asserted, like every other packed
    /// pointer path in the simulator.
    fn check_packable(p: &SharedPtr) -> Result<(), EngineError> {
        if p.va < (1u64 << VA_BITS)
            && p.phase < (1u64 << PHASE_BITS)
            && (p.thread as u64) < (1u64 << THREAD_BITS)
        {
            Ok(())
        } else {
            Err(EngineError::Backend(format!(
                "pointer {p:?} does not fit the Figure-2 packed register \
                 fields ({VA_BITS}-bit va, {PHASE_BITS}-bit phase, \
                 {THREAD_BITS}-bit thread)"
            )))
        }
    }

    /// Record the cycle bill of one served request.
    fn bill(&self, cycles: u64) {
        self.last_cycles.store(cycles, Ordering::Relaxed);
        self.total_cycles.fetch_add(cycles, Ordering::Relaxed);
    }

    /// A fresh single-core Leon3 functional state wired to the
    /// request's base LUT, executing thread, and topology.
    fn core(&self, ctx: &EngineCtx) -> (ArchState, MemSystem) {
        let nt = ctx.layout.numthreads;
        let mut st = ArchState::new(ctx.mythread, nt);
        st.topo = *ctx.topo();
        let mut mem = MemSystem::new(nt);
        mem.base_table = ctx.table.clone();
        (st, mem)
    }

    /// Cost of `inst` on the Leon3 core (result latency, as the
    /// in-order `Leon3Machine` accounts it).
    fn cyc(&self, inst: &Inst) -> u64 {
        self.lat.isa.cost(inst).latency as u64
    }

    /// Lower one `(ptr, inc)` request onto the core —
    /// `ldi rp, <packed>; ldi ri, <inc>; pgas_incr rq, rp, ri` — and
    /// return the incremented pointer plus the cycles the sequence
    /// cost.  Shared by `translate` and `increment` so the lowering
    /// and its accounting cannot drift apart.
    fn replay_one(
        &self,
        st: &mut ArchState,
        mem: &mut MemSystem,
        inc_inst: &Inst,
        p: &SharedPtr,
        inc: u64,
    ) -> Result<(SharedPtr, u64), EngineError> {
        Self::check_packable(p)?;
        st.pc = 0;
        let ld_ptr = Inst::Ldi { rd: R_PTR, imm: pack(p) as i64 };
        let ld_inc = Inst::Ldi { rd: R_INC, imm: inc as i64 };
        step(st, mem, &ld_ptr);
        step(st, mem, &ld_inc);
        step(st, mem, inc_inst);
        let cycles =
            self.cyc(&ld_ptr) + self.cyc(&ld_inc) + self.cyc(inc_inst);
        Ok((unpack(st.r(R_OUT)), cycles))
    }
}

impl AddressEngine for Leon3Engine {
    fn name(&self) -> &'static str {
        "leon3"
    }

    /// The coprocessor serves a layout when the shift/mask pipeline
    /// can (all powers of two) *and* its pointers fit the Figure-2
    /// packed register fields (phase and thread widths).
    fn supports(&self, layout: &ArrayLayout) -> bool {
        layout.hw_supported()
            && (layout.numthreads as u64) <= (1 << THREAD_BITS)
            && layout.blocksize <= (1 << PHASE_BITS)
    }

    fn translate(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        let (l2bs, l2es) = self.gate(ctx)?;
        batch.check()?;
        out.clear();
        out.reserve(batch.len());
        let (mut st, mut mem) = self.core(ctx);
        let inc_inst =
            Inst::PgasIncR { rd: R_OUT, ra: R_PTR, rb: R_INC, l2es, l2bs };
        let mut cycles = 0u64;
        for (p, &inc) in batch.ptrs.iter().zip(&batch.incs) {
            let (q, c) =
                self.replay_one(&mut st, &mut mem, &inc_inst, p, inc)?;
            // + pgas_ldq address generation: LUT lookup + add
            cycles += c + self.lat.l1_hit;
            let sysva = q.translate(&mem.base_table);
            let loc = Locality::from_code(st.cc_loc)
                .expect("coprocessor emitted an invalid locality code");
            out.push(q, sysva, loc);
        }
        self.bill(cycles);
        Ok(())
    }

    fn increment(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        let (l2bs, l2es) = self.gate(ctx)?;
        batch.check()?;
        out.clear();
        out.reserve(batch.len());
        let (mut st, mut mem) = self.core(ctx);
        let inc_inst =
            Inst::PgasIncR { rd: R_OUT, ra: R_PTR, rb: R_INC, l2es, l2bs };
        let mut cycles = 0u64;
        for (p, &inc) in batch.ptrs.iter().zip(&batch.incs) {
            let (q, c) =
                self.replay_one(&mut st, &mut mem, &inc_inst, p, inc)?;
            cycles += c;
            out.push(q);
        }
        self.bill(cycles);
        Ok(())
    }

    /// Walks chain in the coprocessor register file: the start pointer
    /// is materialized once, classified with a zero increment (the
    /// identity, so step 0 reports the start's own locality), then each
    /// step is one in-place `pgas_incr` — the exact register-reuse
    /// shape the compiled `upc_forall` loop has on the board.
    fn walk(
        &self,
        ctx: &EngineCtx,
        start: SharedPtr,
        inc: u64,
        steps: usize,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        let (l2bs, l2es) = self.gate(ctx)?;
        Self::check_packable(&start)?;
        out.clear();
        out.reserve(steps);
        if steps == 0 {
            self.bill(0);
            return Ok(());
        }
        let (mut st, mut mem) = self.core(ctx);
        let self_inc =
            Inst::PgasIncR { rd: R_PTR, ra: R_PTR, rb: R_INC, l2es, l2bs };
        let mut cycles = 0u64;
        // materialize the start pointer, classify it via a zero inc
        let ld_start = Inst::Ldi { rd: R_PTR, imm: pack(&start) as i64 };
        let ld_zero = Inst::Ldi { rd: R_INC, imm: 0 };
        step(&mut st, &mut mem, &ld_start);
        step(&mut st, &mut mem, &ld_zero);
        step(&mut st, &mut mem, &self_inc);
        cycles += self.cyc(&ld_start)
            + self.cyc(&ld_zero)
            + self.cyc(&self_inc)
            + self.lat.l1_hit;
        let emit = |st: &ArchState, mem: &MemSystem, out: &mut BatchOut| {
            let q = unpack(st.r(R_PTR));
            let sysva = q.translate(&mem.base_table);
            let loc = Locality::from_code(st.cc_loc)
                .expect("coprocessor emitted an invalid locality code");
            out.push(q, sysva, loc);
        };
        emit(&st, &mem, out);
        // load the stride once; every further step reuses it
        let ld_inc = Inst::Ldi { rd: R_INC, imm: inc as i64 };
        step(&mut st, &mut mem, &ld_inc);
        cycles += self.cyc(&ld_inc);
        for _ in 1..steps {
            st.pc = 0;
            step(&mut st, &mut mem, &self_inc);
            cycles += self.cyc(&self_inc) + self.lat.l1_hit;
            emit(&st, &mem, out);
        }
        self.bill(cycles);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SoftwareEngine;

    fn fig2_ctx(table: &BaseTable) -> EngineCtx<'_> {
        EngineCtx::new(ArrayLayout::new(4, 4, 4), table, 0).unwrap()
    }

    #[test]
    fn refuses_nonpow2_layouts_like_pow2_engine() {
        let e = Leon3Engine::new();
        // CG's 112-byte element rows: not a power of two
        let layout = ArrayLayout::new(3, 112, 5);
        assert!(!e.supports(&layout));
        let table = BaseTable::regular(5, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut out = BatchOut::new();
        let err = e.walk(&ctx, SharedPtr::NULL, 1, 4, &mut out).unwrap_err();
        assert!(matches!(
            err,
            EngineError::UnsupportedLayout { engine: "leon3", .. }
        ));
        // pow2 geometry but too many threads for the packed field
        assert!(!e.supports(&ArrayLayout::new(4, 4, 2048)));
        // pow2 geometry but blocksize overflowing the phase field
        assert!(!e.supports(&ArrayLayout::new(1 << 17, 4, 4)));
    }

    #[test]
    fn matches_software_on_the_figure2_layout() {
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = fig2_ctx(&table);
        let layout = *ctx.layout();
        let e = Leon3Engine::new();
        let mut batch = PtrBatch::new();
        for i in 0..96u64 {
            batch.push(SharedPtr::for_index(&layout, 0, i * 5), i % 17);
        }
        let (mut hw, mut sw) = (BatchOut::new(), BatchOut::new());
        e.translate(&ctx, &batch, &mut hw).unwrap();
        SoftwareEngine.translate(&ctx, &batch, &mut sw).unwrap();
        assert_eq!(hw, sw);
        let (mut ph, mut ps) = (Vec::new(), Vec::new());
        e.increment(&ctx, &batch, &mut ph).unwrap();
        SoftwareEngine.increment(&ctx, &batch, &mut ps).unwrap();
        assert_eq!(ph, ps);
        e.walk(&ctx, SharedPtr::NULL, 3, 50, &mut hw).unwrap();
        SoftwareEngine.walk(&ctx, SharedPtr::NULL, 3, 50, &mut sw).unwrap();
        assert_eq!(hw, sw);
    }

    #[test]
    fn cycle_accounting_is_deterministic_and_pinned() {
        // One small request: ldi(1) + ldi(1) + pgas_incr(2) + agen(1).
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = fig2_ctx(&table);
        let e = Leon3Engine::new();
        let mut batch = PtrBatch::new();
        batch.push(SharedPtr::NULL, 3);
        let mut out = BatchOut::new();
        e.translate(&ctx, &batch, &mut out).unwrap();
        assert_eq!(e.last_cycles(), 5);
        // increment only: no address-generation charge
        let mut ptrs = Vec::new();
        e.increment(&ctx, &batch, &mut ptrs).unwrap();
        assert_eq!(e.last_cycles(), 4);
        // walk: 5-cycle prologue + 1-cycle stride load + 3/step after
        e.walk(&ctx, SharedPtr::NULL, 1, 100, &mut out).unwrap();
        assert_eq!(e.last_cycles(), 5 + 1 + 99 * 3);
        assert_eq!(e.total_cycles(), 5 + 4 + 303);
        assert!(e.last_runtime_ns() > 0.0);
    }

    #[test]
    fn calibration_returns_positive_coefficients() {
        let e = Leon3Engine::new();
        let (ns_per_ptr, dispatch_ns) = e.calibrate();
        assert!(ns_per_ptr >= 1.0, "measured {ns_per_ptr} ns/ptr");
        assert!(dispatch_ns >= 0.0, "measured {dispatch_ns} ns/batch");
        // measurement is not service: the billing counters are restored
        assert_eq!(e.total_cycles(), 0);
        assert_eq!(e.last_cycles(), 0);
    }

    #[test]
    fn unpackable_pointers_are_refused_not_truncated() {
        // a va past the 38-bit packed field must refuse, not wrap
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = fig2_ctx(&table);
        let e = Leon3Engine::new();
        let huge = SharedPtr { thread: 0, phase: 0, va: 1 << 38 };
        let mut batch = PtrBatch::new();
        batch.push(huge, 1);
        let mut out = BatchOut::new();
        assert!(matches!(
            e.translate(&ctx, &batch, &mut out),
            Err(EngineError::Backend(_))
        ));
        let mut ptrs = Vec::new();
        assert!(e.increment(&ctx, &batch, &mut ptrs).is_err());
        assert!(e.walk(&ctx, huge, 1, 4, &mut out).is_err());
    }

    #[test]
    fn empty_walk_and_empty_batch_are_served() {
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = fig2_ctx(&table);
        let e = Leon3Engine::new();
        let mut out = BatchOut::new();
        e.walk(&ctx, SharedPtr::NULL, 1, 0, &mut out).unwrap();
        assert!(out.is_empty());
        assert_eq!(e.last_cycles(), 0);
        e.translate(&ctx, &PtrBatch::new(), &mut out).unwrap();
        assert!(out.is_empty());
    }
}
