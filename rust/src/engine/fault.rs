//! Deterministic fault injection — the chaos half of the robustness
//! story.  A [`FaultPlan`] is a *seeded* schedule of injectable faults:
//! every draw comes from one [`Xoshiro256`] stream, so any observed
//! fault sequence (and therefore any recovery path through the stack)
//! is reproducible from the `(seed, rates)` pair alone.  Three layers
//! consume the same plan type:
//!
//! * [`ChaosEngine`] wraps any [`AddressEngine`] and injects
//!   backend-level faults (errors, latency spikes) in front of it —
//!   the unit-testable fault surface;
//! * [`EngineSelector`](super::EngineSelector) consults a plan at its
//!   dispatch funnel (`with_chaos`), faulting the *chosen* backend so
//!   the health ladder (circuit breaker + cost-model deadline +
//!   transparent fallback) is exercised without real process churn;
//! * [`RemoteEngine`](super::RemoteEngine) and the daemon's
//!   `ExecBackend` consult a plan at the *wire* (`with_chaos`):
//!   dropped connections, killed workers, corrupt/truncated request
//!   frames, forced stale epochs, and shed storms.
//!
//! The zero-fault invariant is load-bearing: a plan whose rates are all
//! zero ([`FaultSpec::quiet`]) must make every consumer a bit-identical
//! passthrough (`tests/chaos.rs` pins this on all five NPB layouts).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::{AddressEngine, BatchOut, EngineCtx, EngineError, PtrBatch};
use crate::sptr::{ArrayLayout, Locality, SharedPtr};
use crate::util::rng::Xoshiro256;

/// A fault injected in front of an engine dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineFault {
    /// The backend "fails": the dispatch returns
    /// [`EngineError::Backend`] without running.
    Error,
    /// The backend "stalls": the dispatch is billed `ns` extra
    /// nanoseconds, enough to blow the selector's cost-model deadline.
    Spike(u64),
}

/// A fault injected at the wire (remote client or daemon server).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFault {
    /// Sever one worker connection mid-pool (client side).
    Drop,
    /// Kill one worker process outright (client side).
    Kill,
    /// Desync the installed epoch so the next frame is answered
    /// `STATUS_STALE_EPOCH` (either side).
    Stale,
    /// Answer the frame `STATUS_SHED` as if overloaded (server side).
    Shed,
    /// Flip bytes in the request body so the server rejects it
    /// (client side).
    Corrupt,
    /// Cut the request body short — valid framing, short payload
    /// (client side).
    Truncate,
}

/// Per-fault-kind injection rates plus the seed — everything needed to
/// reproduce a fault schedule.  Parsed from the CLI as
/// `SEED[:key=value,...]` (see [`parse`](Self::parse)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// RNG seed; the whole schedule is a pure function of this + rates.
    pub seed: u64,
    /// P(injected backend error) per engine dispatch.
    pub error: f64,
    /// P(injected latency spike) per engine dispatch.
    pub spike: f64,
    /// Billed duration of one spike (defaults far past any deadline).
    pub spike_ns: u64,
    /// P(severed connection) per wire request.
    pub drop: f64,
    /// P(killed worker process) per wire request.
    pub kill: f64,
    /// P(forced stale epoch) per wire request/frame.
    pub stale: f64,
    /// P(shed reply) per served frame.
    pub shed: f64,
    /// P(corrupted request body) per wire request.
    pub corrupt: f64,
    /// P(truncated request body) per wire request.
    pub truncate: f64,
}

impl FaultSpec {
    /// Default billed spike length: 50 ms, far past any priced deadline.
    pub const DEFAULT_SPIKE_NS: u64 = 50_000_000;

    /// All rates zero — the passthrough plan.
    pub fn quiet(seed: u64) -> Self {
        Self {
            seed,
            error: 0.0,
            spike: 0.0,
            spike_ns: Self::DEFAULT_SPIKE_NS,
            drop: 0.0,
            kill: 0.0,
            stale: 0.0,
            shed: 0.0,
            corrupt: 0.0,
            truncate: 0.0,
        }
    }

    /// The default transient-fault storm `--chaos SEED` runs: backend
    /// errors and latency spikes at rates high enough that every run
    /// exercises the fallback ladder, all absorbed by the selector.
    pub fn transient(seed: u64) -> Self {
        Self { error: 0.25, spike: 0.10, ..Self::quiet(seed) }
    }

    /// Same schedule shape, different stream (per-core decorrelation).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Parse `SEED[:key=value,...]`.  `SEED` is decimal or `0x` hex;
    /// keys are the rate fields (`error`, `spike`, `drop`, `kill`,
    /// `stale`, `shed`, `corrupt`, `truncate` — probabilities in
    /// `[0,1]`) plus `spike_ms`.  A bare seed means
    /// [`transient`](Self::transient).
    ///
    /// # Examples
    ///
    /// ```
    /// use pgas_hw::engine::FaultSpec;
    /// let spec = FaultSpec::parse("0xC0FFEE:error=0.5,spike_ms=10").unwrap();
    /// assert_eq!(spec.seed, 0xC0FFEE);
    /// assert_eq!(spec.error, 0.5);
    /// assert_eq!(spec.spike_ns, 10_000_000);
    /// assert!(FaultSpec::parse("7:bogus=1").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<Self, String> {
        let (seed_s, rest) = match s.split_once(':') {
            Some((a, b)) => (a, Some(b)),
            None => (s, None),
        };
        let seed = parse_u64(seed_s)
            .ok_or_else(|| format!("bad chaos seed `{seed_s}`"))?;
        let mut spec = if rest.is_some() {
            Self::quiet(seed)
        } else {
            Self::transient(seed)
        };
        for kv in rest.unwrap_or("").split(',').filter(|p| !p.is_empty()) {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| format!("bad chaos option `{kv}` (want key=value)"))?;
            if k == "spike_ms" {
                let ms: u64 = v
                    .parse()
                    .map_err(|_| format!("bad chaos spike_ms `{v}`"))?;
                spec.spike_ns = ms.saturating_mul(1_000_000);
                continue;
            }
            let p: f64 =
                v.parse().map_err(|_| format!("bad chaos rate `{kv}`"))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("chaos rate `{kv}` outside [0,1]"));
            }
            match k {
                "error" => spec.error = p,
                "spike" => spec.spike = p,
                "drop" => spec.drop = p,
                "kill" => spec.kill = p,
                "stale" => spec.stale = p,
                "shed" => spec.shed = p,
                "corrupt" => spec.corrupt = p,
                "truncate" => spec.truncate = p,
                _ => return Err(format!("unknown chaos fault kind `{k}`")),
            }
        }
        Ok(spec)
    }
}

fn parse_u64(s: &str) -> Option<u64> {
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// A live, seeded fault schedule.  Shared (`Arc`) between an injector
/// site and whoever asserts on its counters; each draw advances the one
/// deterministic RNG stream under a mutex, so concurrent consumers
/// still see *a* reproducible interleaving per run and a bit-exact one
/// single-threaded.
#[derive(Debug)]
pub struct FaultPlan {
    spec: FaultSpec,
    rng: Mutex<Xoshiro256>,
    injected: AtomicU64,
    engine_errors: AtomicU64,
    engine_spikes: AtomicU64,
    wire_faults: AtomicU64,
}

impl FaultPlan {
    pub fn new(spec: FaultSpec) -> Self {
        Self {
            spec,
            rng: Mutex::new(Xoshiro256::new(spec.seed)),
            injected: AtomicU64::new(0),
            engine_errors: AtomicU64::new(0),
            engine_spikes: AtomicU64::new(0),
            wire_faults: AtomicU64::new(0),
        }
    }

    /// The all-rates-zero passthrough plan.
    pub fn quiet(seed: u64) -> Self {
        Self::new(FaultSpec::quiet(seed))
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Draw the fault (if any) for one engine dispatch.
    pub fn engine_fault(&self) -> Option<EngineFault> {
        let s = &self.spec;
        if s.error == 0.0 && s.spike == 0.0 {
            return None; // quiet fast path: no RNG advance, no lock
        }
        let mut rng = self.rng.lock().unwrap();
        if rng.chance(s.error) {
            drop(rng);
            self.injected.fetch_add(1, Ordering::Relaxed);
            self.engine_errors.fetch_add(1, Ordering::Relaxed);
            Some(EngineFault::Error)
        } else if rng.chance(s.spike) {
            drop(rng);
            self.injected.fetch_add(1, Ordering::Relaxed);
            self.engine_spikes.fetch_add(1, Ordering::Relaxed);
            Some(EngineFault::Spike(s.spike_ns))
        } else {
            None
        }
    }

    /// Draw the fault (if any) for one wire request/frame.
    pub fn wire_fault(&self) -> Option<WireFault> {
        let s = &self.spec;
        let rates = [
            (s.drop, WireFault::Drop),
            (s.kill, WireFault::Kill),
            (s.stale, WireFault::Stale),
            (s.shed, WireFault::Shed),
            (s.corrupt, WireFault::Corrupt),
            (s.truncate, WireFault::Truncate),
        ];
        if rates.iter().all(|&(p, _)| p == 0.0) {
            return None;
        }
        let mut rng = self.rng.lock().unwrap();
        for (p, fault) in rates {
            if p > 0.0 && rng.chance(p) {
                drop(rng);
                self.injected.fetch_add(1, Ordering::Relaxed);
                self.wire_faults.fetch_add(1, Ordering::Relaxed);
                return Some(fault);
            }
        }
        None
    }

    /// Total faults injected so far (all kinds).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Injected backend errors so far.
    pub fn engine_errors(&self) -> u64 {
        self.engine_errors.load(Ordering::Relaxed)
    }

    /// Injected latency spikes so far.
    pub fn engine_spikes(&self) -> u64 {
        self.engine_spikes.load(Ordering::Relaxed)
    }

    /// Injected wire faults so far.
    pub fn wire_faults(&self) -> u64 {
        self.wire_faults.load(Ordering::Relaxed)
    }
}

/// An [`AddressEngine`] wrapper that injects faults from a shared
/// [`FaultPlan`] in front of its inner backend.  With a
/// [`FaultSpec::quiet`] plan it is a bit-identical passthrough — the
/// invariant `tests/chaos.rs` pins differentially.
///
/// Injected spikes really sleep, but capped at 1 ms per dispatch so a
/// chaos-wrapped engine cannot stall a test run; the *billed* spike
/// length (what trips the selector's deadline) is the spec's full
/// `spike_ns` and is applied at the selector, not here.
pub struct ChaosEngine<E> {
    inner: E,
    plan: Arc<FaultPlan>,
}

impl<E: AddressEngine> ChaosEngine<E> {
    pub fn new(inner: E, plan: Arc<FaultPlan>) -> Self {
        Self { inner, plan }
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    pub fn plan(&self) -> &Arc<FaultPlan> {
        &self.plan
    }

    /// Draw one engine fault; on `Error`, the injected refusal.
    fn inject(&self) -> Result<(), EngineError> {
        match self.plan.engine_fault() {
            Some(EngineFault::Error) => Err(EngineError::Backend(format!(
                "chaos: injected backend fault (seed {:#x})",
                self.plan.spec().seed
            ))),
            Some(EngineFault::Spike(ns)) => {
                std::thread::sleep(std::time::Duration::from_nanos(
                    ns.min(1_000_000),
                ));
                Ok(())
            }
            None => Ok(()),
        }
    }
}

impl<E: AddressEngine> AddressEngine for ChaosEngine<E> {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn supports(&self, layout: &ArrayLayout) -> bool {
        self.inner.supports(layout)
    }

    fn translate(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        self.inject()?;
        self.inner.translate(ctx, batch, out)
    }

    fn increment(
        &self,
        ctx: &EngineCtx,
        batch: &PtrBatch,
        out: &mut Vec<SharedPtr>,
    ) -> Result<(), EngineError> {
        self.inject()?;
        self.inner.increment(ctx, batch, out)
    }

    fn walk(
        &self,
        ctx: &EngineCtx,
        start: SharedPtr,
        inc: u64,
        steps: usize,
        out: &mut BatchOut,
    ) -> Result<(), EngineError> {
        self.inject()?;
        self.inner.walk(ctx, start, inc, steps, out)
    }

    fn translate_one(
        &self,
        ctx: &EngineCtx,
        ptr: SharedPtr,
        inc: u64,
    ) -> Result<(SharedPtr, u64, Locality), EngineError> {
        self.inject()?;
        self.inner.translate_one(ctx, ptr, inc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SoftwareEngine;
    use crate::sptr::BaseTable;

    #[test]
    fn spec_parse_accepts_seed_and_rates() {
        let t = FaultSpec::parse("42").unwrap();
        assert_eq!(t.seed, 42);
        assert_eq!(t.error, FaultSpec::transient(42).error);
        let q = FaultSpec::parse("0xBEEF:stale=0.5,shed=0.25").unwrap();
        assert_eq!(q.seed, 0xBEEF);
        assert_eq!(q.error, 0.0, "explicit spec starts quiet");
        assert_eq!(q.stale, 0.5);
        assert_eq!(q.shed, 0.25);
        assert!(FaultSpec::parse("notanumber").is_err());
        assert!(FaultSpec::parse("1:error=2.0").is_err());
        assert!(FaultSpec::parse("1:frob=0.1").is_err());
    }

    #[test]
    fn plans_are_reproducible_from_the_seed() {
        let spec = FaultSpec { error: 0.3, spike: 0.2, ..FaultSpec::quiet(99) };
        let a = FaultPlan::new(spec);
        let b = FaultPlan::new(spec);
        let seq_a: Vec<_> = (0..256).map(|_| a.engine_fault()).collect();
        let seq_b: Vec<_> = (0..256).map(|_| b.engine_fault()).collect();
        assert_eq!(seq_a, seq_b);
        assert!(a.engine_errors() > 0 && a.engine_spikes() > 0);
        assert_eq!(a.injected(), a.engine_errors() + a.engine_spikes());
        // a different seed gives a different schedule
        let c = FaultPlan::new(spec.with_seed(100));
        let seq_c: Vec<_> = (0..256).map(|_| c.engine_fault()).collect();
        assert_ne!(seq_a, seq_c);
    }

    #[test]
    fn quiet_plan_never_fires_and_never_locks() {
        let plan = FaultPlan::quiet(7);
        for _ in 0..64 {
            assert_eq!(plan.engine_fault(), None);
            assert_eq!(plan.wire_fault(), None);
        }
        assert_eq!(plan.injected(), 0);
    }

    #[test]
    fn chaos_engine_surfaces_injected_errors_and_counts_them() {
        let plan = Arc::new(FaultPlan::new(FaultSpec {
            error: 1.0,
            ..FaultSpec::quiet(5)
        }));
        let chaos = ChaosEngine::new(SoftwareEngine, Arc::clone(&plan));
        let layout = ArrayLayout::new(4, 8, 4);
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ctx = EngineCtx::new(layout, &table, 0).unwrap();
        let mut batch = PtrBatch::new();
        batch.push(SharedPtr::NULL, 1);
        let mut out = BatchOut::new();
        let err = chaos.translate(&ctx, &batch, &mut out).unwrap_err();
        assert!(matches!(err, EngineError::Backend(ref m) if m.contains("chaos")));
        assert_eq!(plan.engine_errors(), 1);
    }
}
