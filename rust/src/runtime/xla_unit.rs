//! The PJRT-backed executables (compiled in only with the `xla-unit`
//! cargo feature; see the module docs in [`super`]).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{UnitBatchOut, UnitCfg, MAX_THREADS, UNIT_BATCH};
use crate::sptr::{BaseTable, SharedPtr};

impl UnitCfg {
    fn to_vec(self) -> Vec<i32> {
        vec![
            self.log2_blocksize as i32,
            self.log2_elemsize as i32,
            self.log2_numthreads as i32,
            self.mythread as i32,
            self.log2_threads_per_mc as i32,
            self.log2_threads_per_node as i32,
            0,
            0,
        ]
    }
}

/// The loaded PJRT executables.
pub struct XlaUnit {
    client: xla::PjRtClient,
    unit: xla::PjRtLoadedExecutable,
    inc: xla::PjRtLoadedExecutable,
    walker: xla::PjRtLoadedExecutable,
}

fn load_exe(
    client: &xla::PjRtClient,
    dir: &Path,
    name: &str,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = dir.join(format!("{name}.hlo.txt"));
    let text_path = path
        .to_str()
        .with_context(|| format!("non-utf8 path {path:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(text_path)
        .with_context(|| format!("parsing {path:?} (run `make artifacts`)"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {name}"))
}

impl XlaUnit {
    /// Load all artifacts from `dir` (default: ./artifacts).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        if !dir.join("sptr_unit.hlo.txt").exists() {
            bail!(
                "artifacts not found in {dir:?}; run `make artifacts` first"
            );
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            unit: load_exe(&client, dir, "sptr_unit")?,
            inc: load_exe(&client, dir, "sptr_inc")?,
            walker: load_exe(&client, dir, "trace_walker")?,
            client,
        })
    }

    /// Default artifacts directory (next to the workspace root).
    pub fn default_dir() -> PathBuf {
        PathBuf::from("artifacts")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn base_vec(table: &BaseTable) -> Result<Vec<i64>> {
        if table.numthreads() as usize > MAX_THREADS {
            bail!("base table larger than artifact capacity {MAX_THREADS}");
        }
        let mut v = vec![0i64; MAX_THREADS];
        for (t, &b) in table.bases().iter().enumerate() {
            v[t] = b as i64;
        }
        Ok(v)
    }

    /// Fused increment + translate + locality over up to UNIT_BATCH
    /// pointers (shorter batches are padded and trimmed).
    pub fn unit_batch(
        &self,
        cfg: &UnitCfg,
        table: &BaseTable,
        ptrs: &[SharedPtr],
        incs: &[u32],
    ) -> Result<UnitBatchOut> {
        assert_eq!(ptrs.len(), incs.len());
        if ptrs.len() > UNIT_BATCH {
            bail!("batch {} exceeds UNIT_BATCH {UNIT_BATCH}", ptrs.len());
        }
        let n = ptrs.len();
        let mut thread = vec![0i32; UNIT_BATCH];
        let mut phase = vec![0i32; UNIT_BATCH];
        let mut va = vec![0i64; UNIT_BATCH];
        let mut inc = vec![0i32; UNIT_BATCH];
        for (i, p) in ptrs.iter().enumerate() {
            thread[i] = p.thread as i32;
            phase[i] = p.phase as i32;
            va[i] = p.va as i64;
            inc[i] = incs[i] as i32;
        }
        let args = [
            xla::Literal::vec1(&cfg.to_vec()),
            xla::Literal::vec1(&Self::base_vec(table)?),
            xla::Literal::vec1(&thread),
            xla::Literal::vec1(&phase),
            xla::Literal::vec1(&va),
            xla::Literal::vec1(&inc),
        ];
        let result = self.unit.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 5 {
            bail!("unit returned {} outputs, want 5", outs.len());
        }
        let mut it = outs.into_iter();
        let mut out = UnitBatchOut {
            thread: it.next().unwrap().to_vec::<i32>()?,
            phase: it.next().unwrap().to_vec::<i32>()?,
            va: it.next().unwrap().to_vec::<i64>()?,
            sysva: it.next().unwrap().to_vec::<i64>()?,
            loc: it.next().unwrap().to_vec::<i32>()?,
        };
        out.thread.truncate(n);
        out.phase.truncate(n);
        out.va.truncate(n);
        out.sysva.truncate(n);
        out.loc.truncate(n);
        Ok(out)
    }

    /// Increment-only batch; returns the incremented pointers.
    pub fn inc_batch(
        &self,
        cfg: &UnitCfg,
        ptrs: &[SharedPtr],
        incs: &[u32],
    ) -> Result<Vec<SharedPtr>> {
        assert_eq!(ptrs.len(), incs.len());
        if ptrs.len() > UNIT_BATCH {
            bail!("batch {} exceeds UNIT_BATCH {UNIT_BATCH}", ptrs.len());
        }
        let n = ptrs.len();
        let mut thread = vec![0i32; UNIT_BATCH];
        let mut phase = vec![0i32; UNIT_BATCH];
        let mut va = vec![0i64; UNIT_BATCH];
        let mut inc = vec![0i32; UNIT_BATCH];
        for (i, p) in ptrs.iter().enumerate() {
            thread[i] = p.thread as i32;
            phase[i] = p.phase as i32;
            va[i] = p.va as i64;
            inc[i] = incs[i] as i32;
        }
        let args = [
            xla::Literal::vec1(&cfg.to_vec()),
            xla::Literal::vec1(&thread),
            xla::Literal::vec1(&phase),
            xla::Literal::vec1(&va),
            xla::Literal::vec1(&inc),
        ];
        let result = self.inc.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 3 {
            bail!("inc returned {} outputs, want 3", outs.len());
        }
        let mut it = outs.into_iter();
        let nthread = it.next().unwrap().to_vec::<i32>()?;
        let nphase = it.next().unwrap().to_vec::<i32>()?;
        let nva = it.next().unwrap().to_vec::<i64>()?;
        Ok((0..n)
            .map(|i| SharedPtr {
                thread: nthread[i] as u32,
                phase: nphase[i] as u64,
                va: nva[i] as u64,
            })
            .collect())
    }

    /// Walk a pointer WALK_LEN steps; returns (sysva, thread, locality)
    /// per step (step 0 = the start pointer).
    pub fn walk(
        &self,
        cfg: &UnitCfg,
        table: &BaseTable,
        start: &SharedPtr,
        inc: u32,
    ) -> Result<(Vec<i64>, Vec<i32>, Vec<i32>)> {
        let args = [
            xla::Literal::vec1(&cfg.to_vec()),
            xla::Literal::vec1(&Self::base_vec(table)?),
            xla::Literal::from(start.thread as i32),
            xla::Literal::from(start.phase as i32),
            xla::Literal::from(start.va as i64),
            xla::Literal::from(inc as i32),
        ];
        let result = self.walker.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 3 {
            bail!("walker returned {} outputs, want 3", outs.len());
        }
        let mut it = outs.into_iter();
        Ok((
            it.next().unwrap().to_vec::<i64>()?,
            it.next().unwrap().to_vec::<i32>()?,
            it.next().unwrap().to_vec::<i32>()?,
        ))
    }
}
