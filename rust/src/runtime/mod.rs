//! PJRT/XLA runtime bridge for the AOT-compiled batched address-mapping
//! unit (the L1 Pallas kernel lowered through the L2 JAX graph), loaded
//! from `artifacts/*.hlo.txt`.
//!
//! The artifact geometry (batch shape, LUT capacity), the hardware
//! config-register layout ([`UnitCfg`]) and the scalar verification
//! oracle ([`unit_batch_scalar`]) are always compiled; the PJRT
//! executables themselves (`XlaUnit`) need the `xla` crate and the
//! artifacts, so they sit behind the off-by-default `xla-unit` cargo
//! feature — tier-1 builds and tests never touch PJRT.
//!
//! Python runs only at build time (`make artifacts`): the HLO **text**
//! (never a serialized proto — xla_extension 0.5.1 rejects jax ≥ 0.5's
//! 64-bit instruction ids) is parsed, compiled by the PJRT CPU client,
//! and invoked with concrete pointer batches.
//!
//! Callers should not use `XlaUnit` directly: the
//! [`XlaBatchEngine`](crate::engine) adapter serves it through the
//! [`AddressEngine`](crate::engine::AddressEngine) contract, chunking
//! arbitrary batch sizes through the fixed `UNIT_BATCH` artifact shape.

#[cfg(feature = "xla-unit")]
mod xla_unit;

#[cfg(feature = "xla-unit")]
pub use xla_unit::XlaUnit;

use crate::sptr::{BaseTable, SharedPtr};

/// Batch size every artifact was lowered with (monomorphic shapes).
pub const UNIT_BATCH: usize = 8192;
/// Trace length of the walker artifact.
pub const WALK_LEN: usize = 4096;
/// LUT capacity baked into the artifacts.
pub const MAX_THREADS: usize = 64;
/// Config vector length.
pub const CFG_LEN: usize = 8;

/// Hardware-config registers for a batch (mirrors
/// `python/compile/kernels/sptr_unit.py`).
#[derive(Clone, Copy, Debug)]
pub struct UnitCfg {
    pub log2_blocksize: u32,
    pub log2_elemsize: u32,
    pub log2_numthreads: u32,
    pub mythread: u32,
    pub log2_threads_per_mc: u32,
    pub log2_threads_per_node: u32,
}

/// Result of a fused unit batch.
#[derive(Clone, Debug, Default)]
pub struct UnitBatchOut {
    pub thread: Vec<i32>,
    pub phase: Vec<i32>,
    pub va: Vec<i64>,
    pub sysva: Vec<i64>,
    pub loc: Vec<i32>,
}

/// Scalar Rust reference for one batch (the verification oracle's other
/// half): must agree exactly with the XLA unit on pow2 configs.
pub fn unit_batch_scalar(
    cfg: &UnitCfg,
    table: &BaseTable,
    ptrs: &[SharedPtr],
    incs: &[u32],
) -> UnitBatchOut {
    use crate::sptr::{increment_pow2, locality, Locality, Topology};
    let topo = Topology {
        log2_threads_per_mc: cfg.log2_threads_per_mc,
        log2_threads_per_node: cfg.log2_threads_per_node,
    };
    let mut out = UnitBatchOut::default();
    for (p, &inc) in ptrs.iter().zip(incs) {
        let q = increment_pow2(
            p,
            inc as u64,
            cfg.log2_blocksize,
            cfg.log2_elemsize,
            cfg.log2_numthreads,
        );
        out.thread.push(q.thread as i32);
        out.phase.push(q.phase as i32);
        out.va.push(q.va as i64);
        out.sysva.push((table.base(q.thread) + q.va) as i64);
        let l: Locality = locality(q.thread, cfg.mythread, &topo);
        out.loc.push(l as i32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // XLA-backed tests live in rust/tests/xla_unit.rs (they need the
    // artifacts and --features xla-unit); here only the scalar oracle
    // is exercised.
    #[test]
    fn scalar_oracle_basics() {
        let cfg = UnitCfg {
            log2_blocksize: 2,
            log2_elemsize: 2,
            log2_numthreads: 2,
            mythread: 0,
            log2_threads_per_mc: 1,
            log2_threads_per_node: 6,
        };
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ptrs = vec![SharedPtr::NULL; 3];
        let incs = vec![1u32, 4, 5];
        let out = unit_batch_scalar(&cfg, &table, &ptrs, &incs);
        assert_eq!(out.thread, vec![0, 1, 1]);
        assert_eq!(out.phase, vec![1, 0, 1]);
        assert_eq!(out.sysva.len(), 3);
    }
}
