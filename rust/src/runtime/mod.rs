//! PJRT/XLA runtime: loads the AOT-compiled address-mapping unit (the L1
//! Pallas kernel lowered through the L2 JAX graph) from
//! `artifacts/*.hlo.txt` and executes it from Rust.
//!
//! This is the three-layer architecture's run-time bridge: Python runs
//! once at build time (`make artifacts`); here the HLO **text** (never a
//! serialized proto — xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit
//! instruction ids) is parsed, compiled by the PJRT CPU client, and
//! invoked with concrete pointer batches.
//!
//! The coordinator uses it two ways:
//! * as the **batch engine**: bulk shared-pointer increment/translate
//!   offload (the "hardware unit" datapath, vectorized);
//! * as the **verification oracle**: every batch is cross-checked
//!   against the scalar Rust implementation in tests and in
//!   `pgas-hw verify`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::sptr::{BaseTable, SharedPtr};

/// Batch size every artifact was lowered with (monomorphic shapes).
pub const UNIT_BATCH: usize = 8192;
/// Trace length of the walker artifact.
pub const WALK_LEN: usize = 4096;
/// LUT capacity baked into the artifacts.
pub const MAX_THREADS: usize = 64;
/// Config vector length.
pub const CFG_LEN: usize = 8;

/// Hardware-config registers for a batch (mirrors
/// `python/compile/kernels/sptr_unit.py`).
#[derive(Clone, Copy, Debug)]
pub struct UnitCfg {
    pub log2_blocksize: u32,
    pub log2_elemsize: u32,
    pub log2_numthreads: u32,
    pub mythread: u32,
    pub log2_threads_per_mc: u32,
    pub log2_threads_per_node: u32,
}

impl UnitCfg {
    fn to_vec(self) -> Vec<i32> {
        vec![
            self.log2_blocksize as i32,
            self.log2_elemsize as i32,
            self.log2_numthreads as i32,
            self.mythread as i32,
            self.log2_threads_per_mc as i32,
            self.log2_threads_per_node as i32,
            0,
            0,
        ]
    }
}

/// Result of a fused unit batch.
#[derive(Clone, Debug, Default)]
pub struct UnitBatchOut {
    pub thread: Vec<i32>,
    pub phase: Vec<i32>,
    pub va: Vec<i64>,
    pub sysva: Vec<i64>,
    pub loc: Vec<i32>,
}

/// The loaded PJRT executables.
pub struct XlaUnit {
    client: xla::PjRtClient,
    unit: xla::PjRtLoadedExecutable,
    inc: xla::PjRtLoadedExecutable,
    walker: xla::PjRtLoadedExecutable,
}

fn load_exe(
    client: &xla::PjRtClient,
    dir: &Path,
    name: &str,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = dir.join(format!("{name}.hlo.txt"));
    let text_path = path
        .to_str()
        .with_context(|| format!("non-utf8 path {path:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(text_path)
        .with_context(|| format!("parsing {path:?} (run `make artifacts`)"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {name}"))
}

impl XlaUnit {
    /// Load all artifacts from `dir` (default: ./artifacts).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        if !dir.join("sptr_unit.hlo.txt").exists() {
            bail!(
                "artifacts not found in {dir:?}; run `make artifacts` first"
            );
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            unit: load_exe(&client, dir, "sptr_unit")?,
            inc: load_exe(&client, dir, "sptr_inc")?,
            walker: load_exe(&client, dir, "trace_walker")?,
            client,
        })
    }

    /// Default artifacts directory (next to the workspace root).
    pub fn default_dir() -> PathBuf {
        PathBuf::from("artifacts")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn base_vec(table: &BaseTable) -> Result<Vec<i64>> {
        if table.numthreads() as usize > MAX_THREADS {
            bail!("base table larger than artifact capacity {MAX_THREADS}");
        }
        let mut v = vec![0i64; MAX_THREADS];
        for (t, &b) in table.bases().iter().enumerate() {
            v[t] = b as i64;
        }
        Ok(v)
    }

    /// Fused increment + translate + locality over up to UNIT_BATCH
    /// pointers (shorter batches are padded and trimmed).
    pub fn unit_batch(
        &self,
        cfg: &UnitCfg,
        table: &BaseTable,
        ptrs: &[SharedPtr],
        incs: &[u32],
    ) -> Result<UnitBatchOut> {
        assert_eq!(ptrs.len(), incs.len());
        if ptrs.len() > UNIT_BATCH {
            bail!("batch {} exceeds UNIT_BATCH {UNIT_BATCH}", ptrs.len());
        }
        let n = ptrs.len();
        let mut thread = vec![0i32; UNIT_BATCH];
        let mut phase = vec![0i32; UNIT_BATCH];
        let mut va = vec![0i64; UNIT_BATCH];
        let mut inc = vec![0i32; UNIT_BATCH];
        for (i, p) in ptrs.iter().enumerate() {
            thread[i] = p.thread as i32;
            phase[i] = p.phase as i32;
            va[i] = p.va as i64;
            inc[i] = incs[i] as i32;
        }
        let args = [
            xla::Literal::vec1(&cfg.to_vec()),
            xla::Literal::vec1(&Self::base_vec(table)?),
            xla::Literal::vec1(&thread),
            xla::Literal::vec1(&phase),
            xla::Literal::vec1(&va),
            xla::Literal::vec1(&inc),
        ];
        let result = self.unit.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 5 {
            bail!("unit returned {} outputs, want 5", outs.len());
        }
        let mut it = outs.into_iter();
        let mut out = UnitBatchOut {
            thread: it.next().unwrap().to_vec::<i32>()?,
            phase: it.next().unwrap().to_vec::<i32>()?,
            va: it.next().unwrap().to_vec::<i64>()?,
            sysva: it.next().unwrap().to_vec::<i64>()?,
            loc: it.next().unwrap().to_vec::<i32>()?,
        };
        out.thread.truncate(n);
        out.phase.truncate(n);
        out.va.truncate(n);
        out.sysva.truncate(n);
        out.loc.truncate(n);
        Ok(out)
    }

    /// Increment-only batch; returns the incremented pointers.
    pub fn inc_batch(
        &self,
        cfg: &UnitCfg,
        ptrs: &[SharedPtr],
        incs: &[u32],
    ) -> Result<Vec<SharedPtr>> {
        assert_eq!(ptrs.len(), incs.len());
        if ptrs.len() > UNIT_BATCH {
            bail!("batch {} exceeds UNIT_BATCH {UNIT_BATCH}", ptrs.len());
        }
        let n = ptrs.len();
        let mut thread = vec![0i32; UNIT_BATCH];
        let mut phase = vec![0i32; UNIT_BATCH];
        let mut va = vec![0i64; UNIT_BATCH];
        let mut inc = vec![0i32; UNIT_BATCH];
        for (i, p) in ptrs.iter().enumerate() {
            thread[i] = p.thread as i32;
            phase[i] = p.phase as i32;
            va[i] = p.va as i64;
            inc[i] = incs[i] as i32;
        }
        let args = [
            xla::Literal::vec1(&cfg.to_vec()),
            xla::Literal::vec1(&thread),
            xla::Literal::vec1(&phase),
            xla::Literal::vec1(&va),
            xla::Literal::vec1(&inc),
        ];
        let result = self.inc.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 3 {
            bail!("inc returned {} outputs, want 3", outs.len());
        }
        let mut it = outs.into_iter();
        let nthread = it.next().unwrap().to_vec::<i32>()?;
        let nphase = it.next().unwrap().to_vec::<i32>()?;
        let nva = it.next().unwrap().to_vec::<i64>()?;
        Ok((0..n)
            .map(|i| SharedPtr {
                thread: nthread[i] as u32,
                phase: nphase[i] as u64,
                va: nva[i] as u64,
            })
            .collect())
    }

    /// Walk a pointer WALK_LEN steps; returns (sysva, thread, locality)
    /// per step (step 0 = the start pointer).
    pub fn walk(
        &self,
        cfg: &UnitCfg,
        table: &BaseTable,
        start: &SharedPtr,
        inc: u32,
    ) -> Result<(Vec<i64>, Vec<i32>, Vec<i32>)> {
        let args = [
            xla::Literal::vec1(&cfg.to_vec()),
            xla::Literal::vec1(&Self::base_vec(table)?),
            xla::Literal::from(start.thread as i32),
            xla::Literal::from(start.phase as i32),
            xla::Literal::from(start.va as i64),
            xla::Literal::from(inc as i32),
        ];
        let result = self.walker.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 3 {
            bail!("walker returned {} outputs, want 3", outs.len());
        }
        let mut it = outs.into_iter();
        Ok((
            it.next().unwrap().to_vec::<i64>()?,
            it.next().unwrap().to_vec::<i32>()?,
            it.next().unwrap().to_vec::<i32>()?,
        ))
    }
}

/// Scalar Rust reference for one batch (the verification oracle's other
/// half): must agree exactly with the XLA unit on pow2 configs.
pub fn unit_batch_scalar(
    cfg: &UnitCfg,
    table: &BaseTable,
    ptrs: &[SharedPtr],
    incs: &[u32],
) -> UnitBatchOut {
    use crate::sptr::{increment_pow2, locality, Locality, Topology};
    let topo = Topology {
        log2_threads_per_mc: cfg.log2_threads_per_mc,
        log2_threads_per_node: cfg.log2_threads_per_node,
    };
    let mut out = UnitBatchOut::default();
    for (p, &inc) in ptrs.iter().zip(incs) {
        let q = increment_pow2(
            p,
            inc as u64,
            cfg.log2_blocksize,
            cfg.log2_elemsize,
            cfg.log2_numthreads,
        );
        out.thread.push(q.thread as i32);
        out.phase.push(q.phase as i32);
        out.va.push(q.va as i64);
        out.sysva.push((table.base(q.thread) + q.va) as i64);
        let l: Locality = locality(q.thread, cfg.mythread, &topo);
        out.loc.push(l as i32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // XLA-backed tests live in rust/tests/xla_unit.rs (they need the
    // artifacts); here only the scalar oracle is exercised.
    #[test]
    fn scalar_oracle_basics() {
        let cfg = UnitCfg {
            log2_blocksize: 2,
            log2_elemsize: 2,
            log2_numthreads: 2,
            mythread: 0,
            log2_threads_per_mc: 1,
            log2_threads_per_node: 6,
        };
        let table = BaseTable::regular(4, 1 << 32, 1 << 32);
        let ptrs = vec![SharedPtr::NULL; 3];
        let incs = vec![1u32, 4, 5];
        let out = unit_batch_scalar(&cfg, &table, &ptrs, &incs);
        assert_eq!(out.thread, vec![0, 1, 1]);
        assert_eq!(out.phase, vec![1, 0, 1]);
        assert_eq!(out.sysva.len(), 3);
    }
}
