//! A tiny label-resolving assembler over [`crate::isa::Inst`].

use crate::isa::{Cond, Inst, Program};

/// A forward-referenceable label.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Label(usize);

/// Assembler: emit instructions, bind labels, resolve at `finish`.
pub struct Asm {
    insts: Vec<Inst>,
    // for each label: bound target (inst index) once known
    labels: Vec<Option<u32>>,
    // (inst index, label) pairs to patch
    fixups: Vec<(usize, Label)>,
}

impl Asm {
    pub fn new() -> Self {
        Self { insts: Vec::new(), labels: Vec::new(), fixups: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }

    pub fn emit(&mut self, inst: Inst) {
        self.insts.push(inst);
    }

    pub fn label(&mut self) -> Label {
        self.labels.push(None);
        Label(self.labels.len() - 1)
    }

    pub fn bind(&mut self, l: Label) {
        assert!(self.labels[l.0].is_none(), "label bound twice");
        self.labels[l.0] = Some(self.insts.len() as u32);
    }

    /// Emit a conditional branch to `l`.
    pub fn br(&mut self, cond: Cond, ra: u8, l: Label) {
        self.fixups.push((self.insts.len(), l));
        self.insts.push(Inst::Br { cond, ra, target: u32::MAX });
    }

    pub fn jmp(&mut self, l: Label) {
        self.fixups.push((self.insts.len(), l));
        self.insts.push(Inst::Jmp { target: u32::MAX });
    }

    pub fn brloc(&mut self, mask: u8, l: Label) {
        self.fixups.push((self.insts.len(), l));
        self.insts.push(Inst::PgasBrLoc { mask, target: u32::MAX });
    }

    /// Resolve all fixups and produce the program.
    ///
    /// Fails loudly — never emits a silently-bad [`Program`]:
    /// * panics on any label that was created but never bound
    ///   (a dangling `u32::MAX` branch target would otherwise survive
    ///   into the simulator);
    /// * panics on any branch whose resolved target lies outside the
    ///   instruction stream — e.g. a label bound after the final emit
    ///   — with the pc/target/program context `Program::validate`'s
    ///   generic `expect` lacks.
    pub fn finish(mut self, name: &str) -> Program {
        for (idx, l) in std::mem::take(&mut self.fixups) {
            let target = self.labels[l.0].unwrap_or_else(|| {
                panic!(
                    "unbound label L{} referenced by inst {idx} in `{name}`",
                    l.0
                )
            });
            match &mut self.insts[idx] {
                Inst::Br { target: t, .. }
                | Inst::Jmp { target: t }
                | Inst::PgasBrLoc { target: t, .. } => *t = target,
                other => panic!("fixup on non-branch {other}"),
            }
        }
        let n = self.insts.len() as u32;
        for (pc, inst) in self.insts.iter().enumerate() {
            let target = match *inst {
                Inst::Br { target, .. }
                | Inst::Jmp { target }
                | Inst::PgasBrLoc { target, .. } => target,
                _ => continue,
            };
            assert!(
                target < n,
                "branch target {target} at pc {pc} out of range \
                 ({n} instructions) in `{name}`"
            );
        }
        Program::new(name, self.insts)
    }
}

impl Default for Asm {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::IntOp;

    #[test]
    fn forward_and_backward_labels() {
        let mut a = Asm::new();
        let top = a.label();
        let end = a.label();
        a.emit(Inst::Ldi { rd: 1, imm: 3 });
        a.bind(top);
        a.emit(Inst::Opi { op: IntOp::Add, rd: 1, ra: 1, imm: -1 });
        a.br(Cond::Eq, 1, end); // forward
        a.jmp(top); // backward
        a.bind(end);
        a.emit(Inst::Halt);
        let p = a.finish("t");
        assert_eq!(p.insts.len(), 5);
        match p.insts[2] {
            Inst::Br { target, .. } => assert_eq!(target, 4),
            _ => panic!(),
        }
        match p.insts[3] {
            Inst::Jmp { target } => assert_eq!(target, 1),
            _ => panic!(),
        }
    }

    #[test]
    #[should_panic(expected = "unbound label")]
    fn unbound_label_rejected() {
        let mut a = Asm::new();
        let l = a.label();
        a.jmp(l);
        let _ = a.finish("bad");
    }

    #[test]
    #[should_panic(expected = "branch target")]
    fn out_of_range_branch_target_rejected() {
        let mut a = Asm::new();
        // a label bound after the final instruction resolves to
        // one-past-the-end — finish must refuse it loudly
        let l = a.label();
        a.jmp(l);
        a.bind(l);
        let _ = a.finish("bad");
    }

    #[test]
    #[should_panic(expected = "branch target")]
    fn raw_out_of_range_target_rejected() {
        let mut a = Asm::new();
        a.emit(Inst::Br { cond: Cond::Eq, ra: 0, target: 1234 });
        a.emit(Inst::Halt);
        let _ = a.finish("bad");
    }
}
