//! The mini-UPC compiler: a kernel IR and its lowering to SimAlpha in
//! the paper's three configurations.
//!
//! The paper's prototype extends the Berkeley UPC source-to-source
//! compiler: shared-pointer operations are either expanded to the
//! software Algorithm 1 (+ LUT translation), or replaced with `asm()`
//! statements using the new instructions.  Manual optimization
//! ("privatization") is a *source-level* transform: the programmer
//! rewrites shared accesses with affinity-local raw pointers.
//!
//! Correspondingly, here:
//!
//! * a **source variant** is chosen by the kernel builder
//!   ([`SourceVariant::Unoptimized`] uses `Sptr*` ops everywhere;
//!   [`SourceVariant::Privatized`] mirrors the hand-privatized NPB
//!   sources — local traversals through [`Op::LocalAddr`] raw cursors,
//!   with only the genuinely non-privatizable accesses left as `Sptr*`);
//! * a **lowering** is chosen at compile time: [`Lowering::Soft`]
//!   expands `Sptr*` to the software sequences, [`Lowering::Hw`] uses
//!   the PGAS instructions, falling back to software for non-power-of-2
//!   geometries exactly like the prototype (CG's 56016-byte elements).
//!
//! The paper's three measured configurations are then:
//! `(Unoptimized, Soft)`, `(Privatized, Soft)`, `(Unoptimized, Hw)`.

pub mod emit;
pub mod lower;

pub use lower::{compile, CompileOpts, CompileStats, CompiledKernel, Lowering};

use crate::isa::{Cond, FpOp, IntOp, MemWidth};
use crate::upc::{ArrayId, UpcRuntime};

/// Which source text the kernel builder should mirror.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SourceVariant {
    /// The plain UPC source: every shared access via shared pointers.
    Unoptimized,
    /// The hand-tuned source with privatized local accesses.
    Privatized,
}

/// A value: virtual (= architectural, see below) register or immediate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Val {
    R(u8),
    I(i64),
}

/// Kernel IR. Registers are architectural already: the builder hands out
/// `r0..r19` (int) and `f0..f29` (fp) and panics on exhaustion — the
/// builders below are written to stay inside the envelope, mirroring how
/// the real kernels fit the Alpha register file.
#[derive(Clone, Debug)]
pub enum Op {
    // ---- integer ----
    Bin { op: IntOp, d: u8, a: u8, b: Val },
    Mov { d: u8, v: Val },
    // ---- floating point ----
    FBin { op: FpOp, d: u8, a: u8, b: u8 },
    FConst { d: u8, v: f64 },
    FCmpLt { d: u8, a: u8, b: u8 },
    CvtIF { d: u8, a: u8 },
    CvtFI { d: u8, a: u8 },
    // ---- special registers ----
    MyThread { d: u8 },
    Threads { d: u8 },
    PrivBase { d: u8 },
    // ---- private / raw-pointer memory ----
    Ld { w: MemWidth, d: u8, base: u8, disp: i32 },
    St { w: MemWidth, s: u8, base: u8, disp: i32 },
    // ---- UPC shared ops (lowering-dependent) ----
    /// d = &arr[idx]
    SptrInit { d: u8, arr: ArrayId, idx: Val },
    /// p = p + inc elements (through arr's block-cyclic layout)
    SptrInc { p: u8, arr: ArrayId, inc: Val },
    /// d = base + idx elements: the gather form.  `base` holds a
    /// loop-invariant packed pointer (usually &arr[0]), so consecutive
    /// `SptrAt` lanes read only pre-window registers and the pipeline's
    /// window planner can batch them — a data-dependent `SptrInit`
    /// chains through its own base load and never batches.
    SptrAt { d: u8, base: u8, arr: ArrayId, idx: Val },
    SptrLd { w: MemWidth, d: u8, p: u8, disp: i16 },
    SptrSt { w: MemWidth, s: u8, p: u8, disp: i16 },
    /// d = raw sysva of MYTHREAD's chunk of `arr`, element offset `off`
    /// (the manual-privatization cast `(int*)&A[MYTHREAD*chunk]`).
    LocalAddr { d: u8, arr: ArrayId, off: Val },
    // ---- control ----
    For { i: u8, from: Val, to: Val, step: i64, body: Vec<Op> },
    If { cond: Cond, r: u8, then: Vec<Op>, els: Vec<Op> },
    DoWhile { body: Vec<Op>, cond: Cond, r: u8 },
    Barrier,
}

/// A complete kernel module.
#[derive(Clone, Debug)]
pub struct IrModule {
    pub name: String,
    pub ops: Vec<Op>,
}

/// Builder with scoped register pools and structured control flow.
pub struct IrBuilder<'rt> {
    pub rt: &'rt mut UpcRuntime,
    frames: Vec<Vec<Op>>,
    int_free: Vec<u8>,
    fp_free: Vec<u8>,
}

impl<'rt> IrBuilder<'rt> {
    pub fn new(rt: &'rt mut UpcRuntime) -> Self {
        Self {
            rt,
            frames: vec![Vec::new()],
            int_free: (0..20).rev().collect(),
            fp_free: (0..30).rev().collect(),
        }
    }

    fn push(&mut self, op: Op) {
        self.frames.last_mut().unwrap().push(op);
    }

    // ---- register management ----

    /// Allocate an integer register for the rest of its scope.
    pub fn it(&mut self) -> u8 {
        self.int_free.pop().expect("int register pool exhausted")
    }

    pub fn ft(&mut self) -> u8 {
        self.fp_free.pop().expect("fp register pool exhausted")
    }

    pub fn free_i(&mut self, r: u8) {
        debug_assert!(!self.int_free.contains(&r));
        self.int_free.push(r);
    }

    pub fn free_f(&mut self, r: u8) {
        debug_assert!(!self.fp_free.contains(&r));
        self.fp_free.push(r);
    }

    // ---- straight-line ops ----

    pub fn mov(&mut self, d: u8, v: Val) {
        self.push(Op::Mov { d, v });
    }

    pub fn iconst(&mut self, v: i64) -> u8 {
        let d = self.it();
        self.mov(d, Val::I(v));
        d
    }

    pub fn bin(&mut self, op: IntOp, d: u8, a: u8, b: Val) {
        self.push(Op::Bin { op, d, a, b });
    }

    pub fn add(&mut self, d: u8, a: u8, b: Val) {
        self.bin(IntOp::Add, d, a, b);
    }

    pub fn fbin(&mut self, op: FpOp, d: u8, a: u8, b: u8) {
        self.push(Op::FBin { op, d, a, b });
    }

    pub fn fconst(&mut self, v: f64) -> u8 {
        let d = self.ft();
        self.push(Op::FConst { d, v });
        d
    }

    pub fn fcmplt(&mut self, d: u8, a: u8, b: u8) {
        self.push(Op::FCmpLt { d, a, b });
    }

    pub fn cvt_if(&mut self, d: u8, a: u8) {
        self.push(Op::CvtIF { d, a });
    }

    pub fn cvt_fi(&mut self, d: u8, a: u8) {
        self.push(Op::CvtFI { d, a });
    }

    pub fn mythread(&mut self) -> u8 {
        let d = self.it();
        self.push(Op::MyThread { d });
        d
    }

    pub fn threads(&mut self) -> u8 {
        let d = self.it();
        self.push(Op::Threads { d });
        d
    }

    pub fn priv_base(&mut self) -> u8 {
        let d = self.it();
        self.push(Op::PrivBase { d });
        d
    }

    pub fn ld(&mut self, w: MemWidth, d: u8, base: u8, disp: i32) {
        self.push(Op::Ld { w, d, base, disp });
    }

    pub fn st(&mut self, w: MemWidth, s: u8, base: u8, disp: i32) {
        self.push(Op::St { w, s, base, disp });
    }

    // ---- shared ops ----

    pub fn sptr_init(&mut self, arr: ArrayId, idx: Val) -> u8 {
        let d = self.it();
        self.push(Op::SptrInit { d, arr, idx });
        d
    }

    pub fn sptr_inc(&mut self, p: u8, arr: ArrayId, inc: Val) {
        self.push(Op::SptrInc { p, arr, inc });
    }

    /// `d = &base_ptr[idx]` through `arr`'s layout, leaving the base
    /// cursor untouched.  `d` may alias the index register (the lanes
    /// of a gather loop reuse their index registers as destinations).
    pub fn sptr_at(&mut self, d: u8, base: u8, arr: ArrayId, idx: Val) {
        self.push(Op::SptrAt { d, base, arr, idx });
    }

    pub fn sptr_ld(&mut self, w: MemWidth, d: u8, p: u8, disp: i16) {
        self.push(Op::SptrLd { w, d, p, disp });
    }

    pub fn sptr_st(&mut self, w: MemWidth, s: u8, p: u8, disp: i16) {
        self.push(Op::SptrSt { w, s, p, disp });
    }

    pub fn local_addr(&mut self, arr: ArrayId, off: Val) -> u8 {
        let d = self.it();
        self.push(Op::LocalAddr { d, arr, off });
        d
    }

    // ---- control flow ----

    /// `for i in (from..to).step_by(step)` — `i` is freed afterwards.
    pub fn for_range<F>(&mut self, from: Val, to: Val, step: i64, f: F)
    where
        F: FnOnce(&mut Self, u8),
    {
        let i = self.it();
        self.frames.push(Vec::new());
        f(self, i);
        let body = self.frames.pop().unwrap();
        self.push(Op::For { i, from, to, step, body });
        self.free_i(i);
    }

    pub fn iff<F>(&mut self, cond: Cond, r: u8, f: F)
    where
        F: FnOnce(&mut Self),
    {
        self.frames.push(Vec::new());
        f(self);
        let then = self.frames.pop().unwrap();
        self.push(Op::If { cond, r, then, els: Vec::new() });
    }

    pub fn if_else<F, G>(&mut self, cond: Cond, r: u8, f: F, g: G)
    where
        F: FnOnce(&mut Self),
        G: FnOnce(&mut Self),
    {
        self.frames.push(Vec::new());
        f(self);
        let then = self.frames.pop().unwrap();
        self.frames.push(Vec::new());
        g(self);
        let els = self.frames.pop().unwrap();
        self.push(Op::If { cond, r, then, els });
    }

    pub fn do_while<F>(&mut self, cond: Cond, r: u8, f: F)
    where
        F: FnOnce(&mut Self),
    {
        self.frames.push(Vec::new());
        f(self);
        let body = self.frames.pop().unwrap();
        self.push(Op::DoWhile { body, cond, r });
    }

    pub fn barrier(&mut self) {
        self.push(Op::Barrier);
    }

    pub fn finish(mut self, name: &str) -> IrModule {
        assert_eq!(self.frames.len(), 1, "unbalanced control-flow frames");
        IrModule { name: name.to_string(), ops: self.frames.pop().unwrap() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_scopes_and_pools() {
        let mut rt = UpcRuntime::new(4);
        let a = rt.alloc_shared("a", 4, 8, 64);
        let mut b = IrBuilder::new(&mut rt);
        let acc = b.it();
        b.mov(acc, Val::I(0));
        let p = b.sptr_init(a, Val::I(0));
        b.for_range(Val::I(0), Val::I(64), 1, |b, _i| {
            let t = b.it();
            b.sptr_ld(MemWidth::U64, t, p, 0);
            b.add(acc, acc, Val::R(t));
            b.sptr_inc(p, a, Val::I(1));
            b.free_i(t);
        });
        let m = b.finish("sum");
        assert_eq!(m.name, "sum");
        assert!(matches!(m.ops.last().unwrap(), Op::For { .. }));
    }

    #[test]
    #[should_panic(expected = "exhausted")]
    fn pool_exhaustion_panics() {
        let mut rt = UpcRuntime::new(2);
        let mut b = IrBuilder::new(&mut rt);
        for _ in 0..25 {
            let _ = b.it();
        }
    }
}
