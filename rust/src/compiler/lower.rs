//! Lowering: kernel IR → SimAlpha, in `Soft` or `Hw` configuration.
//!
//! `Soft` expands every shared-pointer operation into the software
//! sequences the Berkeley runtime executes (Algorithm 1 with real
//! divides when THREADS is a run-time value, plus LUT translation
//! through a private copy of the base-address table).  `Hw` emits the
//! paper's new instructions, with the same software expansion as a
//! fallback when an array's geometry is not power-of-2 — exactly the
//! prototype compiler's behaviour on CG's 56016-byte elements.
//!
//! Scratch-register budget (never handed to the IR builder):
//! `r20..r25` (S0..S5), `r27`, `r30`; ABI registers per [`crate::sim::abi`].

use std::collections::BTreeMap;

use super::emit::Asm;
use super::{IrModule, Op, Val};
use crate::isa::{Cond, Inst, IntOp, MemWidth, Program, ZERO};
use crate::mem::seg_base;
use crate::sim::abi;
use crate::sptr::{pack, ArrayLayout, THREAD_BITS, VA_BITS};
use crate::upc::UpcRuntime;
use crate::util::log2_exact;

const S0: u8 = 20;
const S1: u8 = 21;
const S2: u8 = 22;
const S3: u8 = 23;
const S4: u8 = 24;
const S5: u8 = 25;
const SCR: u8 = abi::R_SCRATCH; // r27
const SCR2: u8 = abi::R_SCRATCH2; // r30

/// Private-space offset of the base-table copy used by soft translation.
pub const BT_OFF: i32 = 0x800;
/// Private-space offset of the f64 constant pool.
pub const FPOOL_OFF: i32 = 0x0;
/// Private-space slot standing in for the GCC spill slot reloaded after
/// every volatile PGAS store (see [`CompileOpts::volatile_stores`]).
pub const VOLATILE_SPILL_OFF: i32 = 0xFF8;

/// Shared-pointer lowering strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Lowering {
    /// Software Algorithm 1 + LUT translation (the unmodified compiler).
    Soft,
    /// The paper's PGAS instructions (with software fallback).
    Hw,
}

/// Compile-time options.
#[derive(Clone, Copy, Debug)]
pub struct CompileOpts {
    pub lowering: Lowering,
    /// Berkeley "static mode": THREADS is a compile-time constant, so
    /// the soft path can strength-reduce /THREADS to shifts. The paper's
    /// Gem5 runs use the dynamic mode (false); the Leon3 vector-addition
    /// microbenchmark compares both (Fig. 15).
    pub static_threads: bool,
    pub numthreads: u32,
    /// Model the prototype's `volatile` + memory-clobber `asm()` PGAS
    /// stores (paper 6.1): after every hardware store GCC must reload a
    /// register-cached value, emitted here as one extra private load.
    /// This is the effect the paper blames for HW code trailing the
    /// manually-privatized code by ~10–13% on IS and MG.
    pub volatile_stores: bool,
}

impl CompileOpts {
    pub fn soft(numthreads: u32) -> Self {
        Self {
            lowering: Lowering::Soft,
            static_threads: false,
            numthreads,
            volatile_stores: true,
        }
    }

    pub fn hw(numthreads: u32) -> Self {
        Self {
            lowering: Lowering::Hw,
            static_threads: false,
            numthreads,
            volatile_stores: true,
        }
    }
}

/// What the compiler did with the shared ops (the paper reports these:
/// "the generated code contained 309 shared address incrementations but
/// 20 of those were [software]; 236 loads and stores [were hardware]").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CompileStats {
    pub hw_incs: u32,
    pub soft_incs: u32,
    pub hw_mems: u32,
    pub soft_mems: u32,
    pub insts: u32,
}

/// A compiled kernel.
#[derive(Clone, Debug)]
pub struct CompiledKernel {
    pub program: Program,
    pub stats: CompileStats,
}

struct Ctx<'a> {
    asm: Asm,
    rt: &'a UpcRuntime,
    opts: CompileOpts,
    stats: CompileStats,
    fpool: BTreeMap<u64, i32>, // f64 bits -> private offset
}

fn negate(c: Cond) -> Cond {
    match c {
        Cond::Eq => Cond::Ne,
        Cond::Ne => Cond::Eq,
        Cond::Lt => Cond::Ge,
        Cond::Ge => Cond::Lt,
        Cond::Le => Cond::Gt,
        Cond::Gt => Cond::Le,
    }
}

fn collect_fconsts(ops: &[Op], pool: &mut BTreeMap<u64, i32>) {
    for op in ops {
        match op {
            Op::FConst { v, .. } => {
                let bits = v.to_bits();
                let next = FPOOL_OFF + (pool.len() as i32) * 8;
                pool.entry(bits).or_insert(next);
            }
            Op::For { body, .. } | Op::DoWhile { body, .. } => {
                collect_fconsts(body, pool)
            }
            Op::If { then, els, .. } => {
                collect_fconsts(then, pool);
                collect_fconsts(els, pool);
            }
            _ => {}
        }
    }
}

impl<'a> Ctx<'a> {
    /// Emit `d = a op imm`, materializing wide immediates via SCR2.
    fn bin_imm(&mut self, op: IntOp, d: u8, a: u8, imm: i64) {
        if (i32::MIN as i64..=i32::MAX as i64).contains(&imm) {
            self.asm.emit(Inst::Opi { op, rd: d, ra: a, imm: imm as i32 });
        } else {
            self.asm.emit(Inst::Ldi { rd: SCR2, imm });
            self.asm.emit(Inst::Opr { op, rd: d, ra: a, rb: SCR2 });
        }
    }

    fn bin(&mut self, op: IntOp, d: u8, a: u8, b: Val) {
        match b {
            Val::R(r) => self.asm.emit(Inst::Opr { op, rd: d, ra: a, rb: r }),
            Val::I(i) => self.bin_imm(op, d, a, i),
        }
    }

    // ---------------- soft shared-pointer expansion ----------------

    /// Algorithm 1 in software over the packed pointer in `p`.
    fn soft_inc(&mut self, p: u8, layout: &ArrayLayout, inc: Val) {
        self.stats.soft_incs += 1;
        let a = &mut self.asm;
        let bs = layout.blocksize as i64;
        let es = layout.elemsize as i64;
        // unpack: S0 = old phase, S1 = thread, S2 = va
        a.emit(Inst::Opi { op: IntOp::Srl, rd: S0, ra: p, imm: (THREAD_BITS + VA_BITS) as i32 });
        a.emit(Inst::Opi { op: IntOp::Srl, rd: S1, ra: p, imm: VA_BITS as i32 });
        a.emit(Inst::Opi { op: IntOp::And, rd: S1, ra: S1, imm: 0x3FF });
        a.emit(Inst::Ldi { rd: SCR2, imm: ((1u64 << VA_BITS) - 1) as i64 });
        a.emit(Inst::Opr { op: IntOp::And, rd: S2, ra: p, rb: SCR2 });
        // S3 = phinc = phase + inc
        match inc {
            Val::R(r) => a.emit(Inst::Opr { op: IntOp::Add, rd: S3, ra: S0, rb: r }),
            Val::I(i) => a.emit(Inst::Opi { op: IntOp::Add, rd: S3, ra: S0, imm: i as i32 }),
        }
        // S4 = thinc = phinc / bs ; S5 = nphase = phinc % bs
        // (blocksize is a compile-time constant in UPC: strength-reduced
        // when pow2 even by the unmodified compiler)
        if let Some(l2) = log2_exact(bs as u64) {
            a.emit(Inst::Opi { op: IntOp::Srl, rd: S4, ra: S3, imm: l2 as i32 });
            a.emit(Inst::Opi { op: IntOp::And, rd: S5, ra: S3, imm: (bs - 1) as i32 });
        } else {
            a.emit(Inst::Ldi { rd: SCR2, imm: bs });
            a.emit(Inst::Opr { op: IntOp::Div, rd: S4, ra: S3, rb: SCR2 });
            a.emit(Inst::Opr { op: IntOp::Rem, rd: S5, ra: S3, rb: SCR2 });
        }
        // S1 = tsum = thread + thinc
        a.emit(Inst::Opr { op: IntOp::Add, rd: S1, ra: S1, rb: S4 });
        // S4 = blockinc = tsum / T ; S1 = nthread = tsum % T
        let t = self.opts.numthreads as i64;
        if self.opts.static_threads && (t as u64).is_power_of_two() {
            let l2 = log2_exact(t as u64).unwrap();
            a.emit(Inst::Opi { op: IntOp::Srl, rd: S4, ra: S1, imm: l2 as i32 });
            a.emit(Inst::Opi { op: IntOp::And, rd: S1, ra: S1, imm: (t - 1) as i32 });
        } else {
            // dynamic THREADS: real divide + remainder (the expensive
            // path the paper's unoptimized baseline takes)
            a.emit(Inst::Opr { op: IntOp::Div, rd: S4, ra: S1, rb: abi::R_THREADS });
            a.emit(Inst::Opr { op: IntOp::Rem, rd: S1, ra: S1, rb: abi::R_THREADS });
        }
        // SCR = eaddrinc = (nphase - oldphase) + blockinc * bs
        a.emit(Inst::Opr { op: IntOp::Sub, rd: SCR, ra: S5, rb: S0 });
        if let Some(l2) = log2_exact(bs as u64) {
            a.emit(Inst::Opi { op: IntOp::Sll, rd: S4, ra: S4, imm: l2 as i32 });
        } else {
            a.emit(Inst::Opi { op: IntOp::Mul, rd: S4, ra: S4, imm: bs as i32 });
        }
        a.emit(Inst::Opr { op: IntOp::Add, rd: SCR, ra: SCR, rb: S4 });
        // S2 = va + eaddrinc * es
        if let Some(l2) = log2_exact(es as u64) {
            a.emit(Inst::Opi { op: IntOp::Sll, rd: SCR, ra: SCR, imm: l2 as i32 });
        } else {
            a.emit(Inst::Opi { op: IntOp::Mul, rd: SCR, ra: SCR, imm: es as i32 });
        }
        a.emit(Inst::Opr { op: IntOp::Add, rd: S2, ra: S2, rb: SCR });
        // repack p = (nphase << 48) | (nthread << 38) | va
        a.emit(Inst::Opi { op: IntOp::Sll, rd: S5, ra: S5, imm: (THREAD_BITS + VA_BITS) as i32 });
        a.emit(Inst::Opi { op: IntOp::Sll, rd: S1, ra: S1, imm: VA_BITS as i32 });
        a.emit(Inst::Opr { op: IntOp::Or, rd: p, ra: S5, rb: S1 });
        a.emit(Inst::Opr { op: IntOp::Or, rd: p, ra: p, rb: S2 });
    }

    /// Software translation + access: LUT lookup through the private
    /// base-table copy, then the load/store.
    fn soft_mem(&mut self, w: MemWidth, reg: u8, p: u8, disp: i16, store: bool) {
        self.stats.soft_mems += 1;
        let a = &mut self.asm;
        a.emit(Inst::Opi { op: IntOp::Srl, rd: S1, ra: p, imm: VA_BITS as i32 });
        a.emit(Inst::Opi { op: IntOp::And, rd: S1, ra: S1, imm: 0x3FF });
        a.emit(Inst::Opi { op: IntOp::Sll, rd: S1, ra: S1, imm: 3 });
        a.emit(Inst::Opr { op: IntOp::Add, rd: S1, ra: S1, rb: abi::R_PRIV });
        a.emit(Inst::Ld { w: MemWidth::U64, rd: S1, base: S1, disp: BT_OFF });
        a.emit(Inst::Ldi { rd: SCR2, imm: ((1u64 << VA_BITS) - 1) as i64 });
        a.emit(Inst::Opr { op: IntOp::And, rd: S2, ra: p, rb: SCR2 });
        a.emit(Inst::Opr { op: IntOp::Add, rd: S2, ra: S1, rb: S2 });
        if store {
            a.emit(Inst::St { w, rs: reg, base: S2, disp: disp as i32 });
        } else {
            a.emit(Inst::Ld { w, rd: reg, base: S2, disp: disp as i32 });
        }
    }

    // ---------------- shared-op dispatch ----------------

    fn sptr_inc(&mut self, p: u8, layout: &ArrayLayout, inc: Val) {
        let hw_ok = self.opts.lowering == Lowering::Hw && layout.hw_supported();
        if !hw_ok {
            return self.soft_inc(p, layout, inc);
        }
        let (l2bs, l2es, _) = layout.log2s().unwrap();
        let (l2bs, l2es) = (l2bs as u8, l2es as u8);
        match inc {
            Val::I(0) => {}
            Val::I(c) if c > 0 && (c as u64).is_power_of_two() => {
                self.stats.hw_incs += 1;
                self.asm.emit(Inst::PgasIncI {
                    rd: p,
                    ra: p,
                    l2es,
                    l2bs,
                    l2inc: (c as u64).trailing_zeros() as u8,
                });
            }
            Val::I(c) if c > 0 && (c as u64).count_ones() == 2 => {
                // the prototype's 2-immediates trick: inc by 3 = 1 + 2
                self.stats.hw_incs += 2;
                let c = c as u64;
                let lo = c.trailing_zeros() as u8;
                let hi = (63 - c.leading_zeros()) as u8;
                for l2inc in [lo, hi] {
                    self.asm.emit(Inst::PgasIncI { rd: p, ra: p, l2es, l2bs, l2inc });
                }
            }
            Val::I(c) => {
                self.stats.hw_incs += 1;
                self.asm.emit(Inst::Ldi { rd: SCR, imm: c });
                self.asm.emit(Inst::PgasIncR { rd: p, ra: p, rb: SCR, l2es, l2bs });
            }
            Val::R(r) => {
                self.stats.hw_incs += 1;
                self.asm.emit(Inst::PgasIncR { rd: p, ra: p, rb: r, l2es, l2bs });
            }
        }
    }

    /// `d = base + idx` elements, leaving `base` untouched.  The Hw
    /// path is a single `PgasIncR` with `rd != ra` — the shape the
    /// pipeline's window planner batches, since `base` is never
    /// written inside the window.
    fn sptr_at(&mut self, d: u8, base: u8, layout: &ArrayLayout, idx: Val) {
        let hw_ok = self.opts.lowering == Lowering::Hw && layout.hw_supported();
        if hw_ok {
            let (l2bs, l2es, _) = layout.log2s().unwrap();
            let (l2bs, l2es) = (l2bs as u8, l2es as u8);
            self.stats.hw_incs += 1;
            match idx {
                Val::R(r) => self
                    .asm
                    .emit(Inst::PgasIncR { rd: d, ra: base, rb: r, l2es, l2bs }),
                Val::I(c) => {
                    self.asm.emit(Inst::Ldi { rd: SCR, imm: c });
                    self.asm.emit(Inst::PgasIncR {
                        rd: d,
                        ra: base,
                        rb: SCR,
                        l2es,
                        l2bs,
                    });
                }
            }
        } else {
            // software: copy the cursor, then Algorithm 1 on the copy.
            // When `d` aliases the index register, stage the index
            // through SCR first or the copy would clobber it (soft_inc
            // reads the increment before its own SCR write).
            let inc = match idx {
                Val::R(r) if r == d => {
                    self.asm.emit(Inst::Opr {
                        op: IntOp::Add,
                        rd: SCR,
                        ra: r,
                        rb: ZERO,
                    });
                    Val::R(SCR)
                }
                other => other,
            };
            self.asm.emit(Inst::Opr { op: IntOp::Add, rd: d, ra: base, rb: ZERO });
            self.soft_inc(d, layout, inc);
        }
    }

    fn sptr_mem(&mut self, w: MemWidth, reg: u8, p: u8, disp: i16, store: bool, layout: &ArrayLayout) {
        let hw_ok = self.opts.lowering == Lowering::Hw && layout.hw_supported();
        if hw_ok {
            self.stats.hw_mems += 1;
            if store {
                self.asm.emit(Inst::PgasSt { w, rs: reg, rptr: p, disp });
                if self.opts.volatile_stores {
                    // GCC reload forced by the memory clobber: one spilled
                    // loop value comes back from the stack (paper 6.1)
                    self.asm.emit(Inst::Ld {
                        w: MemWidth::U64,
                        rd: SCR2,
                        base: abi::R_PRIV,
                        disp: VOLATILE_SPILL_OFF,
                    });
                }
            } else {
                self.asm.emit(Inst::PgasLd { w, rd: reg, rptr: p, disp });
            }
        } else {
            self.soft_mem(w, reg, p, disp, store);
        }
    }

    // ---------------- statement walk ----------------

    fn lower_ops(&mut self, ops: &[Op]) {
        for op in ops {
            self.lower_op(op);
        }
    }

    fn lower_op(&mut self, op: &Op) {
        match op {
            Op::Bin { op, d, a, b } => self.bin(*op, *d, *a, *b),
            Op::Mov { d, v } => match v {
                Val::R(r) => self.asm.emit(Inst::Opr {
                    op: IntOp::Add,
                    rd: *d,
                    ra: *r,
                    rb: ZERO,
                }),
                Val::I(i) => self.asm.emit(Inst::Ldi { rd: *d, imm: *i }),
            },
            Op::FBin { op, d, a, b } => {
                self.asm.emit(Inst::Fop { op: *op, fd: *d, fa: *a, fb: *b })
            }
            Op::FConst { d, v } => {
                let off = self.fpool[&v.to_bits()];
                self.asm.emit(Inst::Ld {
                    w: MemWidth::F64,
                    rd: *d,
                    base: abi::R_PRIV,
                    disp: off,
                });
            }
            Op::FCmpLt { d, a, b } => {
                self.asm.emit(Inst::FCmpLt { rd: *d, fa: *a, fb: *b })
            }
            Op::CvtIF { d, a } => self.asm.emit(Inst::CvtIF { fd: *d, ra: *a }),
            Op::CvtFI { d, a } => self.asm.emit(Inst::CvtFI { rd: *d, fa: *a }),
            Op::MyThread { d } => self.asm.emit(Inst::Opr {
                op: IntOp::Add,
                rd: *d,
                ra: abi::R_MYTHREAD,
                rb: ZERO,
            }),
            Op::Threads { d } => self.asm.emit(Inst::Opr {
                op: IntOp::Add,
                rd: *d,
                ra: abi::R_THREADS,
                rb: ZERO,
            }),
            Op::PrivBase { d } => self.asm.emit(Inst::Opr {
                op: IntOp::Add,
                rd: *d,
                ra: abi::R_PRIV,
                rb: ZERO,
            }),
            Op::Ld { w, d, base, disp } => {
                self.asm.emit(Inst::Ld { w: *w, rd: *d, base: *base, disp: *disp })
            }
            Op::St { w, s, base, disp } => {
                self.asm.emit(Inst::St { w: *w, rs: *s, base: *base, disp: *disp })
            }
            Op::SptrInit { d, arr, idx } => {
                let a = self.rt.array(*arr);
                match idx {
                    Val::I(c) => {
                        let packed = pack(&a.ptr(*c as u64)) as i64;
                        self.asm.emit(Inst::Ldi { rd: *d, imm: packed });
                    }
                    Val::R(r) => {
                        let packed = pack(&a.ptr(0)) as i64;
                        self.asm.emit(Inst::Ldi { rd: *d, imm: packed });
                        let layout = a.layout;
                        self.sptr_inc(*d, &layout, Val::R(*r));
                    }
                }
            }
            Op::SptrInc { p, arr, inc } => {
                let layout = self.rt.array(*arr).layout;
                self.sptr_inc(*p, &layout, *inc);
            }
            Op::SptrAt { d, base, arr, idx } => {
                let layout = self.rt.array(*arr).layout;
                self.sptr_at(*d, *base, &layout, *idx);
            }
            Op::SptrLd { w, d, p, disp } => {
                // layout of the array the pointer came from is tracked by
                // the builder; for loads/stores only hw-support matters,
                // so we use the pointer's array via disp-free convention:
                // the builder guarantees `p` was initialized from an
                // array; conservatively we must know pow2-ness. We thread
                // it through SptrLd's width-independent path: the builder
                // stores the ArrayId in the op (see SptrLdA) — kept
                // simple: all SptrLd go through the same decision as the
                // *last* SptrInit/SptrInc... (handled in lower(), which
                // rewrites SptrLd/SptrSt to carry the ArrayId).
                unreachable!("SptrLd must be rewritten to SptrLdA {w:?} {d} {p} {disp}")
            }
            Op::SptrSt { .. } => unreachable!("SptrSt must be rewritten"),
            Op::LocalAddr { d, arr, off } => {
                let a = self.rt.array(*arr);
                let base_va = a.base_va as i64;
                let es = a.layout.elemsize as i64;
                // d = ((MYTHREAD + 1) << 32) + base_va + off*es
                self.asm.emit(Inst::Opi {
                    op: IntOp::Add,
                    rd: *d,
                    ra: abi::R_MYTHREAD,
                    imm: 1,
                });
                self.asm.emit(Inst::Opi { op: IntOp::Sll, rd: *d, ra: *d, imm: 32 });
                match off {
                    Val::I(c) => {
                        self.bin_imm(IntOp::Add, *d, *d, base_va + c * es);
                    }
                    Val::R(r) => {
                        self.bin_imm(IntOp::Add, *d, *d, base_va);
                        if let Some(l2) = log2_exact(es as u64) {
                            self.asm.emit(Inst::Opi {
                                op: IntOp::Sll,
                                rd: SCR,
                                ra: *r,
                                imm: l2 as i32,
                            });
                        } else {
                            self.asm.emit(Inst::Opi {
                                op: IntOp::Mul,
                                rd: SCR,
                                ra: *r,
                                imm: es as i32,
                            });
                        }
                        self.asm.emit(Inst::Opr {
                            op: IntOp::Add,
                            rd: *d,
                            ra: *d,
                            rb: SCR,
                        });
                    }
                }
            }
            Op::For { i, from, to, step, body } => {
                assert!(*step > 0, "for_range requires positive step");
                self.lower_op(&Op::Mov { d: *i, v: *from });
                let top = self.asm.label();
                let exit = self.asm.label();
                self.asm.bind(top);
                match to {
                    Val::I(c) => self.bin_imm(IntOp::CmpLt, SCR, *i, *c),
                    Val::R(r) => self.asm.emit(Inst::Opr {
                        op: IntOp::CmpLt,
                        rd: SCR,
                        ra: *i,
                        rb: *r,
                    }),
                }
                self.asm.br(Cond::Eq, SCR, exit);
                self.lower_ops(body);
                self.bin_imm(IntOp::Add, *i, *i, *step);
                self.asm.jmp(top);
                self.asm.bind(exit);
            }
            Op::If { cond, r, then, els } => {
                let after = self.asm.label();
                if els.is_empty() {
                    self.asm.br(negate(*cond), *r, after);
                    self.lower_ops(then);
                    self.asm.bind(after);
                } else {
                    let else_l = self.asm.label();
                    self.asm.br(negate(*cond), *r, else_l);
                    self.lower_ops(then);
                    self.asm.jmp(after);
                    self.asm.bind(else_l);
                    self.lower_ops(els);
                    self.asm.bind(after);
                }
            }
            Op::DoWhile { body, cond, r } => {
                let top = self.asm.label();
                self.asm.bind(top);
                self.lower_ops(body);
                self.asm.br(*cond, *r, top);
            }
            Op::Barrier => self.asm.emit(Inst::Barrier),
        }
    }
}

/// Compile an IR module against a runtime instance.
pub fn compile(m: &IrModule, rt: &UpcRuntime, opts: &CompileOpts) -> CompiledKernel {
    assert_eq!(opts.numthreads, rt.numthreads, "opts/runtime thread mismatch");
    let mut fpool = BTreeMap::new();
    collect_fconsts(&m.ops, &mut fpool);
    assert!(fpool.len() * 8 <= BT_OFF as usize, "f64 const pool overflow");

    // pointer-register -> array bindings, updated flow-sensitively as
    // SptrInit ops are encountered (registers are pool-reused, so a
    // register may point into different arrays at different points; the
    // binding visible at each SptrLd/SptrSt is the syntactically
    // preceding SptrInit, which is exactly the builder's discipline).
    let mut ptr_arrays: std::collections::HashMap<u8, crate::upc::ArrayId> =
        std::collections::HashMap::new();

    let mut ctx = Ctx {
        asm: Asm::new(),
        rt,
        opts: *opts,
        stats: CompileStats::default(),
        fpool,
    };

    // ---------------- prologue ----------------
    if opts.lowering == Lowering::Hw {
        // initialize the special 'threads' register and the base LUT
        // with the paper's initialization instructions (Table 1)
        ctx.asm.emit(Inst::PgasSetThreads { ra: abi::R_THREADS });
    }
    for t in 0..rt.numthreads {
        ctx.asm.emit(Inst::Ldi { rd: SCR, imm: t as i64 });
        ctx.asm.emit(Inst::Ldi { rd: SCR2, imm: seg_base(t) as i64 });
        if opts.lowering == Lowering::Hw {
            ctx.asm.emit(Inst::PgasSetBase { rthread: SCR, raddr: SCR2 });
        }
        // private copy of the LUT for the soft translation path
        ctx.bin_imm(IntOp::Sll, SCR, SCR, 3);
        ctx.asm.emit(Inst::Opr { op: IntOp::Add, rd: SCR, ra: SCR, rb: abi::R_PRIV });
        ctx.asm.emit(Inst::St { w: MemWidth::U64, rs: SCR2, base: SCR, disp: BT_OFF });
    }
    for (bits, off) in ctx.fpool.clone() {
        ctx.asm.emit(Inst::Ldi { rd: SCR, imm: bits as i64 });
        ctx.asm.emit(Inst::St { w: MemWidth::U64, rs: SCR, base: abi::R_PRIV, disp: off });
    }

    // ---------------- body ----------------
    // rewrite SptrLd/SptrSt via the pointer->array map at dispatch time
    fn lower_with_mem(
        ctx: &mut Ctx,
        ops: &[Op],
        ptr_arrays: &mut std::collections::HashMap<u8, crate::upc::ArrayId>,
    ) {
        for op in ops {
            match op {
                Op::SptrInit { d, arr, .. } => {
                    ptr_arrays.insert(*d, *arr);
                    ctx.lower_op(op);
                }
                Op::SptrAt { d, arr, .. } => {
                    ptr_arrays.insert(*d, *arr);
                    ctx.lower_op(op);
                }
                Op::SptrLd { w, d, p, disp } => {
                    let arr = *ptr_arrays
                        .get(p)
                        .unwrap_or_else(|| panic!("r{p} used as sptr but never SptrInit"));
                    let layout = ctx.rt.array(arr).layout;
                    ctx.sptr_mem(*w, *d, *p, *disp, false, &layout);
                }
                Op::SptrSt { w, s, p, disp } => {
                    let arr = *ptr_arrays
                        .get(p)
                        .unwrap_or_else(|| panic!("r{p} used as sptr but never SptrInit"));
                    let layout = ctx.rt.array(arr).layout;
                    ctx.sptr_mem(*w, *s, *p, *disp, true, &layout);
                }
                Op::For { i, from, to, step, body } => {
                    assert!(*step > 0);
                    ctx.lower_op(&Op::Mov { d: *i, v: *from });
                    let top = ctx.asm.label();
                    let exit = ctx.asm.label();
                    ctx.asm.bind(top);
                    match to {
                        Val::I(c) => ctx.bin_imm(IntOp::CmpLt, SCR, *i, *c),
                        Val::R(r) => ctx.asm.emit(Inst::Opr {
                            op: IntOp::CmpLt,
                            rd: SCR,
                            ra: *i,
                            rb: *r,
                        }),
                    }
                    ctx.asm.br(Cond::Eq, SCR, exit);
                    lower_with_mem(ctx, body, ptr_arrays);
                    ctx.bin_imm(IntOp::Add, *i, *i, *step);
                    ctx.asm.jmp(top);
                    ctx.asm.bind(exit);
                }
                Op::If { cond, r, then, els } => {
                    let after = ctx.asm.label();
                    if els.is_empty() {
                        ctx.asm.br(negate(*cond), *r, after);
                        lower_with_mem(ctx, then, ptr_arrays);
                        ctx.asm.bind(after);
                    } else {
                        let else_l = ctx.asm.label();
                        ctx.asm.br(negate(*cond), *r, else_l);
                        lower_with_mem(ctx, then, ptr_arrays);
                        ctx.asm.jmp(after);
                        ctx.asm.bind(else_l);
                        lower_with_mem(ctx, els, ptr_arrays);
                        ctx.asm.bind(after);
                    }
                }
                Op::DoWhile { body, cond, r } => {
                    let top = ctx.asm.label();
                    ctx.asm.bind(top);
                    lower_with_mem(ctx, body, ptr_arrays);
                    ctx.asm.br(*cond, *r, top);
                }
                other => ctx.lower_op(other),
            }
        }
    }
    lower_with_mem(&mut ctx, &m.ops, &mut ptr_arrays);

    ctx.asm.emit(Inst::Halt);
    let mut stats = ctx.stats;
    let program = ctx.asm.finish(&m.name);
    stats.insts = program.len() as u32;
    CompiledKernel { program, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::IrBuilder;
    use crate::cpu::CpuModel;
    use crate::sim::{Machine, MachineCfg};
    use crate::upc::UpcRuntime;

    /// Sum a shared array both ways; results must match and the HW
    /// variant must execute far fewer instructions.
    fn sum_kernel(rt: &mut UpcRuntime, arr: crate::upc::ArrayId, n: u64) -> IrModule {
        let mut b = IrBuilder::new(rt);
        let acc = b.it();
        b.mov(acc, Val::I(0));
        let p = b.sptr_init(arr, Val::I(0));
        b.for_range(Val::I(0), Val::I(n as i64), 1, |b, _| {
            let t = b.it();
            b.sptr_ld(MemWidth::U64, t, p, 0);
            b.add(acc, acc, Val::R(t));
            b.sptr_inc(p, arr, Val::I(1));
            b.free_i(t);
        });
        // only thread 0 stores the result
        let m = b.mythread();
        b.iff(Cond::Eq, m, |b| {
            let pb = b.priv_base();
            b.st(MemWidth::U64, acc, pb, 0);
            b.free_i(pb);
        });
        b.finish("sum")
    }

    fn run_sum(lowering: Lowering, threads: u32, n: u64) -> (u64, u64, CompileStats) {
        let mut rt = UpcRuntime::new(threads);
        let arr = rt.alloc_shared("a", 4, 8, n);
        let m = sum_kernel(&mut rt, arr, n);
        let opts = CompileOpts { lowering, static_threads: false, numthreads: threads, volatile_stores: true };
        let ck = compile(&m, &rt, &opts);
        let mut machine = Machine::new(MachineCfg::new(threads, CpuModel::Atomic));
        for i in 0..n {
            rt.write_u64(machine.mem_mut(), arr, i, i * 3);
        }
        let res = machine.run(&ck.program);
        let got = machine.mem.read(
            MemWidth::U64,
            crate::mem::seg_base(0) + crate::mem::PRIV_OFF,
        );
        (got, res.total.instructions, ck.stats)
    }

    #[test]
    fn soft_and_hw_agree_and_hw_is_cheaper() {
        let n = 64u64;
        let want: u64 = (0..n).map(|i| i * 3).sum();
        let (soft_sum, soft_insts, soft_stats) = run_sum(Lowering::Soft, 4, n);
        let (hw_sum, hw_insts, hw_stats) = run_sum(Lowering::Hw, 4, n);
        assert_eq!(soft_sum, want);
        assert_eq!(hw_sum, want);
        assert!(
            soft_insts > 3 * hw_insts,
            "soft {soft_insts} should dwarf hw {hw_insts}"
        );
        assert_eq!(soft_stats.hw_incs, 0);
        assert!(hw_stats.hw_incs > 0);
        assert_eq!(hw_stats.soft_incs, 0);
    }

    #[test]
    fn nonpow2_geometry_falls_back_to_soft() {
        let mut rt = UpcRuntime::new(4);
        // elemsize 56016: the CG w/w_tmp case
        let arr = rt.alloc_shared("w", 1, 56016, 16);
        let mut b = IrBuilder::new(&mut rt);
        let p = b.sptr_init(arr, Val::I(0));
        b.sptr_inc(p, arr, Val::I(1));
        let m = b.finish("fallback");
        let ck = compile(&m, &rt, &CompileOpts::hw(4));
        assert_eq!(ck.stats.hw_incs, 0);
        assert_eq!(ck.stats.soft_incs, 1);
    }

    #[test]
    fn two_bit_increment_uses_two_immediates() {
        let mut rt = UpcRuntime::new(4);
        let arr = rt.alloc_shared("a", 4, 8, 64);
        let mut b = IrBuilder::new(&mut rt);
        let p = b.sptr_init(arr, Val::I(0));
        b.sptr_inc(p, arr, Val::I(3)); // 3 = 1 + 2
        let m = b.finish("inc3");
        let ck = compile(&m, &rt, &CompileOpts::hw(4));
        assert_eq!(ck.stats.hw_incs, 2);
        let n_inci = ck
            .program
            .insts
            .iter()
            .filter(|i| matches!(i, Inst::PgasIncI { .. }))
            .count();
        assert_eq!(n_inci, 2);
    }

    /// `sptr_at` (the gather form, rd may alias the index register)
    /// must index identically under both lowerings — including when
    /// the destination aliases the index register, where the soft
    /// path has to stage the index before clobbering the cursor copy.
    #[test]
    fn sptr_at_matches_host_indexing_in_both_lowerings() {
        for lowering in [Lowering::Soft, Lowering::Hw] {
            let threads = 4u32;
            let mut rt = UpcRuntime::new(threads);
            let n = 32u64;
            let arr = rt.alloc_shared("a", 4, 8, n);
            let mut b = IrBuilder::new(&mut rt);
            let base = b.sptr_init(arr, Val::I(0));
            let acc = b.iconst(0);
            b.for_range(Val::I(0), Val::I(8), 1, |b, i| {
                let j = b.it();
                b.bin(IntOp::Mul, j, i, Val::I(3)); // idx = 3*i
                b.sptr_at(j, base, arr, Val::R(j)); // d aliases idx
                let t = b.it();
                b.sptr_ld(MemWidth::U64, t, j, 0);
                b.add(acc, acc, Val::R(t));
                b.free_i(t);
                b.free_i(j);
            });
            let m = b.mythread();
            b.iff(Cond::Eq, m, |b| {
                let pb = b.priv_base();
                b.st(MemWidth::U64, acc, pb, 0);
                b.free_i(pb);
            });
            let module = b.finish("gather_at");
            let opts = CompileOpts {
                lowering,
                static_threads: false,
                numthreads: threads,
                volatile_stores: true,
            };
            let ck = compile(&module, &rt, &opts);
            let mut machine =
                Machine::new(MachineCfg::new(threads, CpuModel::Atomic));
            for i in 0..n {
                rt.write_u64(machine.mem_mut(), arr, i, i * 7 + 1);
            }
            machine.run(&ck.program);
            let got = machine.mem.read(
                MemWidth::U64,
                crate::mem::seg_base(0) + crate::mem::PRIV_OFF,
            );
            let want: u64 = (0..8u64).map(|i| (3 * i) * 7 + 1).sum();
            assert_eq!(got, want, "{lowering:?}");
            match lowering {
                Lowering::Hw => assert!(ck.stats.hw_incs >= 1),
                Lowering::Soft => assert!(ck.stats.soft_incs >= 1),
            }
        }
    }

    #[test]
    fn privatized_local_cursor_matches_shared_walk() {
        // write MYTHREAD's own block-cyclic elements through a local
        // cursor; read back through host-side indexing
        let threads = 4u32;
        let mut rt = UpcRuntime::new(threads);
        let arr = rt.alloc_shared("a", 8, 8, 8 * threads as u64);
        let mut b = IrBuilder::new(&mut rt);
        let cursor = b.local_addr(arr, Val::I(0));
        b.for_range(Val::I(0), Val::I(8), 1, |b, i| {
            let t = b.it();
            b.bin(IntOp::Sll, t, i, Val::I(3));
            let addr = b.it();
            b.bin(IntOp::Add, addr, cursor, Val::R(t));
            b.st(MemWidth::U64, i, addr, 0);
            b.free_i(addr);
            b.free_i(t);
        });
        let m = b.finish("privwrite");
        let ck = compile(&m, &rt, &CompileOpts::soft(threads));
        let mut machine = Machine::new(MachineCfg::new(threads, CpuModel::Atomic));
        machine.run(&ck.program);
        // thread t's j-th local element is logical element t*8 + j
        for t in 0..threads as u64 {
            for j in 0..8u64 {
                let got = rt.read_u64(machine.mem_mut(), arr, t * 8 + j);
                assert_eq!(got, j, "thread {t} elem {j}");
            }
        }
    }
}
